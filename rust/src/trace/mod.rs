//! SPMD workload traces: sequences of collective operations with data
//! sizes, replayed through the simulator under a chosen algorithm suite.
//!
//! This is the workload-level view of the paper's claims: not "one
//! broadcast is faster" but "an application that broadcasts, reduces and
//! exchanges every iteration finishes sooner on multi-core-aware
//! schedules". Generators cover the two SPMD shapes the paper's
//! introduction motivates: iterative solvers (allreduce-dominated) and
//! transform/shuffle codes (all-to-all dominated).

use crate::collectives::TargetHeuristic;
use crate::coordinator::{
    AllreduceAlgo, AlltoallAlgo, BroadcastAlgo, Communicator, GatherAlgo,
};
use crate::sched::Schedule;
use crate::sim::{simulate, SimParams};
use crate::util::Rng;

/// One collective in a workload trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceOp {
    Broadcast { root: usize, bytes: u64 },
    Gather { root: usize, bytes: u64 },
    Allreduce { bytes: u64 },
    AllToAll { bytes_per_pair: u64 },
}

/// A sequence of collectives.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub ops: Vec<TraceOp>,
}

impl Trace {
    /// Data-parallel training: per step one gradient allreduce plus an
    /// occasional model broadcast (checkpoint restore / elastic join).
    pub fn training(steps: usize, grad_bytes: u64) -> Self {
        let mut ops = Vec::with_capacity(steps + steps / 50 + 1);
        ops.push(TraceOp::Broadcast { root: 0, bytes: grad_bytes });
        for s in 0..steps {
            ops.push(TraceOp::Allreduce { bytes: grad_bytes });
            if s % 50 == 49 {
                ops.push(TraceOp::Broadcast { root: 0, bytes: grad_bytes });
            }
        }
        Self { ops }
    }

    /// FFT/shuffle-style: all-to-all every iteration, gather at the end.
    pub fn shuffle(iters: usize, bytes_per_pair: u64, result_bytes: u64) -> Self {
        let mut ops: Vec<TraceOp> =
            (0..iters).map(|_| TraceOp::AllToAll { bytes_per_pair }).collect();
        ops.push(TraceOp::Gather { root: 0, bytes: result_bytes });
        Self { ops }
    }

    /// Mixed workload with seeded randomness.
    pub fn mixed(n: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let ops = (0..n)
            .map(|_| match rng.gen_range(0..4) {
                0 => TraceOp::Broadcast { root: 0, bytes: 1 << rng.gen_range(10..22) },
                1 => TraceOp::Gather { root: 0, bytes: 1 << rng.gen_range(10..18) },
                2 => TraceOp::Allreduce { bytes: 1 << rng.gen_range(12..24) },
                _ => TraceOp::AllToAll { bytes_per_pair: 1 << rng.gen_range(8..14) },
            })
            .collect();
        Self { ops }
    }
}

/// Which algorithm family serves each op during replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suite {
    /// Multi-core-oblivious classics (binomial / inverse-binomial /
    /// pairwise / ring).
    Flat,
    /// The paper's multi-core-aware algorithms.
    McAware,
}

impl Suite {
    pub fn name(&self) -> &'static str {
        match self {
            Suite::Flat => "flat",
            Suite::McAware => "mc-aware",
        }
    }
}

/// Replay result.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub total_time: f64,
    pub per_op: Vec<f64>,
    pub ext_messages: usize,
}

/// Replay a trace on a communicator under a suite, timing each op with
/// the continuous simulator.
pub fn replay(
    comm: &Communicator,
    trace: &Trace,
    suite: Suite,
    base_params: &SimParams,
) -> crate::Result<TraceReport> {
    let mut total = 0.0;
    let mut per_op = Vec::with_capacity(trace.ops.len());
    let mut ext_messages = 0;
    for op in &trace.ops {
        let (schedule, total_bytes): (Schedule, u64) = match *op {
            TraceOp::Broadcast { root, bytes } => (
                match suite {
                    Suite::Flat => comm.broadcast(BroadcastAlgo::Binomial, root),
                    Suite::McAware => comm.broadcast(
                        BroadcastAlgo::McAware(TargetHeuristic::CoverageAware),
                        root,
                    ),
                },
                bytes,
            ),
            TraceOp::Gather { root, bytes } => (
                match suite {
                    Suite::Flat => comm.gather(GatherAlgo::InverseBinomial, root),
                    Suite::McAware => comm.gather(GatherAlgo::McAware, root),
                },
                bytes,
            ),
            TraceOp::Allreduce { bytes } => (
                match suite {
                    Suite::Flat => comm.allreduce(AllreduceAlgo::Ring)?,
                    Suite::McAware => comm.allreduce(AllreduceAlgo::HierarchicalMc)?,
                },
                bytes,
            ),
            TraceOp::AllToAll { bytes_per_pair } => {
                let n = comm.num_ranks() as u64;
                (
                    match suite {
                        Suite::Flat => comm.alltoall(AlltoallAlgo::Pairwise),
                        Suite::McAware => {
                            let slots = comm
                                .cluster
                                .degree(0)
                                .min(comm.placement.ranks_on(0).len())
                                .max(1);
                            comm.alltoall(AlltoallAlgo::LeaderAggregated(slots))
                        }
                    },
                    bytes_per_pair * n * n,
                )
            }
        };
        // Size the schedule itself: MsgSpec spreads the op's total
        // payload over the schedule's chunk space.
        let schedule = schedule.with_total_bytes(total_bytes);
        let rep = simulate(&comm.cluster, &comm.placement, &schedule, base_params)?;
        total += rep.t_end;
        ext_messages += rep.ext_messages;
        per_op.push(rep.t_end);
    }
    Ok(TraceReport { total_time: total, per_op, ext_messages })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::switched;

    #[test]
    fn generators_shape() {
        let t = Trace::training(100, 1 << 20);
        assert_eq!(
            t.ops.iter().filter(|o| matches!(o, TraceOp::Allreduce { .. })).count(),
            100
        );
        let s = Trace::shuffle(5, 1024, 1 << 20);
        assert_eq!(s.ops.len(), 6);
        let m1 = Trace::mixed(20, 7);
        let m2 = Trace::mixed(20, 7);
        assert_eq!(m1.ops, m2.ops);
    }

    #[test]
    fn mc_suite_beats_flat_on_training_trace() {
        let comm = Communicator::block(switched(4, 4, 2));
        let trace = Trace::training(10, 4 << 20);
        let params = SimParams::lan_cluster();
        let flat = replay(&comm, &trace, Suite::Flat, &params).unwrap();
        let mc = replay(&comm, &trace, Suite::McAware, &params).unwrap();
        assert!(
            mc.total_time < flat.total_time,
            "mc {} vs flat {}",
            mc.total_time,
            flat.total_time
        );
    }

    #[test]
    fn replay_reports_per_op() {
        let comm = Communicator::block(switched(2, 2, 1));
        let trace = Trace::mixed(8, 1);
        let rep =
            replay(&comm, &trace, Suite::McAware, &SimParams::lan_cluster()).unwrap();
        assert_eq!(rep.per_op.len(), 8);
        assert!(rep.total_time > 0.0);
    }
}
