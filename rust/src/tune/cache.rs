//! Concurrent decision cache: fingerprint → [`Decision`], sharded for
//! tuning-as-a-service traffic.
//!
//! The cache is the serving layer of the tuner: one instance fields
//! queries from many threads at once, so the hot path is engineered to
//! hold **no exclusive lock and allocate nothing**:
//!
//! ```text
//!   get_or_tune(cluster, placement, collective, cfg)
//!        │
//!        ▼
//!   live_digest ─────────── streaming FNV-1a over the live inputs
//!        │                  (bit-identical to Fingerprint::new().digest(),
//!        │                  relabeling included, zero allocation)
//!        ▼
//!   shard = mix(digest)     N RwLock shards, independent locks
//!        │
//!        ├── read lock ───▶ one hash probe, confirm with the interned
//!        │   HIT            Arc<Fingerprint> via Fingerprint::matches
//!        │                  (streaming equality, zero allocation), bump
//!        │                  a relaxed per-shard atomic, mark the CLOCK
//!        │                  bit, clone the Arc<Decision> — done. No
//!        │                  writer lock, no Fingerprint built.
//!        ▼
//!   MISS: warm probe ─────▶ family index: same topology/collective/knobs,
//!        │                  nearest msg_bytes size class → that entry's
//!        │                  winning candidate seeds select_seeded
//!        │                  (ordering-only: the pick is bit-identical to
//!        │                  a cold select — see selector docs)
//!        ▼
//!   write lock (one shard): double-probe (another thread may have won
//!                           the race — serve its entry), CLOCK-evict if
//!                           at capacity, insert interned fingerprint +
//!                           Arc<Decision>
//! ```
//!
//! A hit returns the cached decision — including the schedule, whose
//! rank numbering is valid because equal fingerprints imply the exact
//! same cluster + placement (see [`super::fingerprint`]). Decisions are
//! handed out as [`Arc<Decision>`], so readers never hold any lock while
//! materializing or executing a schedule.
//!
//! **Capacity.** The cache is bounded ([`CacheConfig::capacity`], split
//! evenly across shards) with CLOCK (second-chance) eviction: every
//! probe sets the entry's referenced bit through a relaxed atomic (still
//! under the read lock), and the eviction hand clears bits until it
//! finds an unreferenced victim. Eviction runs *before* insertion, so a
//! just-inserted entry is structurally never its own victim.
//!
//! **Determinism.** Selection is deterministic, so when two threads race
//! to tune the same fingerprint both compute bit-identical decisions and
//! the loser adopts the winner's entry — callers can never observe torn
//! or divergent decisions (`tests/cache_concurrency.rs` hammers this).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering::Relaxed};
use std::sync::{Arc, RwLock};

use crate::topology::{Cluster, Placement};

use super::fingerprint::{live_digest, live_family_digest, Fingerprint};
use super::registry::{CandidateId, Collective};
use super::selector::{select_seeded, Decision, TuneCfg};

/// Warm-start search window, in msg_bytes size classes (powers of two)
/// on either side of the query. Decisions cluster by size class (the
/// segment sweep flips at bandwidth crossovers), so a neighbor further
/// than 4 octaves away is no better a guess than the registry order.
const WARM_CLASS_WINDOW: u32 = 4;

/// Shard count and total entry capacity for a [`DecisionCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// Number of independently locked shards (rounded up to a power of
    /// two). More shards = less writer interference; the default
    /// comfortably outstrips any realistic thread count.
    pub shards: usize,
    /// Total cached decisions across all shards (split evenly); at
    /// capacity, CLOCK eviction reclaims the coldest entry per insert.
    pub capacity: usize,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self { shards: 16, capacity: 1 << 16 }
    }
}

/// Counters for observability (E9/E16 benches, the trainer's end-of-run
/// report, tests). Hit/miss/invalidation/eviction counts are summed over
/// the per-shard relaxed atomics; a concurrent snapshot is therefore
/// approximate while traffic is in flight and exact once it quiesces.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    /// Entries actually removed by [`DecisionCache::invalidate`] (calls
    /// that found nothing to remove are not counted).
    pub invalidations: usize,
    /// Live entries across all shards.
    pub entries: usize,
    /// Entries reclaimed by CLOCK eviction at capacity.
    pub evictions: usize,
    /// Misses whose tune was warm-started from a neighboring size class
    /// (the pick is bit-identical either way; this counts seeding only).
    pub warm_hits: usize,
    /// Shard count (fixed at construction).
    pub shards: usize,
    /// Live entries per shard, in shard order.
    pub per_shard: Vec<usize>,
}

/// One interned cache entry. `digest` is denormalized from `fp` so
/// eviction/invalidation can unlink from the shard index without
/// re-walking the fingerprint.
#[derive(Debug)]
struct Entry {
    digest: u64,
    fp: Arc<Fingerprint>,
    decision: Arc<Decision>,
    /// CLOCK referenced bit: set by every probe (relaxed store under the
    /// read lock), cleared by the eviction hand's first pass.
    referenced: AtomicBool,
}

/// One shard's entry storage: a slab with a free list (stable slot
/// numbers for the CLOCK hand) plus a digest → slots index. Buckets are
/// tiny vectors because digest collisions are ~nonexistent; equality is
/// always confirmed against the full fingerprint.
#[derive(Debug, Default)]
struct Slots {
    index: HashMap<u64, Vec<u32>>,
    slab: Vec<Option<Entry>>,
    free: Vec<u32>,
    hand: usize,
}

impl Slots {
    fn len(&self) -> usize {
        self.slab.len() - self.free.len()
    }

    /// Unlink one slot: take the entry, recycle the slot, drop it from
    /// the digest index. Returns the (family digest, digest) pair the
    /// caller needs to unlink the warm index (outside the shard lock).
    fn remove_slot(&mut self, slot: u32) -> (u64, u64) {
        let e = self.slab[slot as usize].take().expect("indexed slot is live");
        self.free.push(slot);
        if let Some(bucket) = self.index.get_mut(&e.digest) {
            if let Some(p) = bucket.iter().position(|&s| s == slot) {
                bucket.swap_remove(p);
            }
            if bucket.is_empty() {
                self.index.remove(&e.digest);
            }
        }
        (e.fp.family_digest(), e.digest)
    }

    /// CLOCK second chance: advance the hand, clearing referenced bits,
    /// until an unreferenced entry turns up; evict it. The first full
    /// sweep clears every bit, so the walk always terminates within two
    /// laps. Runs before insertion — the incoming entry has no slot yet
    /// and can never be its own victim.
    fn evict_one(&mut self) -> Option<(u64, u64)> {
        let n = self.slab.len();
        if self.len() == 0 {
            return None;
        }
        for _ in 0..2 * n + 1 {
            let i = self.hand;
            self.hand = (self.hand + 1) % n;
            let Some(e) = self.slab[i].as_ref() else { continue };
            if e.referenced.swap(false, Relaxed) {
                continue;
            }
            return Some(self.remove_slot(i as u32));
        }
        unreachable!("a full CLOCK sweep clears every referenced bit");
    }

    /// Store `entry` in a recycled or fresh slot and index it.
    fn insert(&mut self, entry: Entry) {
        let digest = entry.digest;
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = Some(entry);
                s
            }
            None => {
                self.slab.push(Some(entry));
                (self.slab.len() - 1) as u32
            }
        };
        self.index.entry(digest).or_default().push(slot);
    }
}

/// One independently locked shard plus its relaxed counters: the hit
/// path touches only this struct — a read lock and two relaxed stores.
#[derive(Debug, Default)]
struct Shard {
    slots: RwLock<Slots>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    invalidations: AtomicUsize,
    evictions: AtomicUsize,
}

/// Warm-index record: enough to seed a neighbor's tune without touching
/// the owning shard ([`CandidateId`] is `Copy`).
#[derive(Debug, Clone, Copy)]
struct WarmEntry {
    digest: u64,
    msg_bytes: u64,
    choice: CandidateId,
}

/// Sharded, internally synchronized decision cache. Shareable by
/// reference across threads (`&self` everywhere); [`crate::tune::Tuned`]
/// is the cfg-carrying facade over it.
#[derive(Debug)]
pub struct DecisionCache {
    shards: Vec<Shard>,
    shard_cap: usize,
    /// Warm-start index: family digest (fingerprint minus size class) →
    /// cached sizes in that family. Touched only on miss / insert /
    /// evict / invalidate — never on the hit path.
    warm: RwLock<HashMap<u64, Vec<WarmEntry>>>,
    warm_hits: AtomicUsize,
}

impl Default for DecisionCache {
    fn default() -> Self {
        Self::new()
    }
}

impl DecisionCache {
    pub fn new() -> Self {
        Self::with_config(CacheConfig::default())
    }

    /// Cache with explicit shard count and capacity (tests and benches;
    /// serving deployments are fine with [`CacheConfig::default`]).
    pub fn with_config(cfg: CacheConfig) -> Self {
        let shards = cfg.shards.max(1).next_power_of_two();
        Self {
            shards: (0..shards).map(|_| Shard::default()).collect(),
            shard_cap: cfg.capacity.max(shards).div_ceil(shards),
            warm: RwLock::new(HashMap::new()),
            warm_hits: AtomicUsize::new(0),
        }
    }

    /// Fibonacci-mix the digest into a shard index: FNV's low bits are
    /// well scrambled but the multiply spreads any residual structure
    /// across the (power-of-two) shard count.
    fn shard_of(&self, digest: u64) -> &Shard {
        let mixed = (digest.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize;
        &self.shards[mixed & (self.shards.len() - 1)]
    }

    /// Look up the decision for this (topology, collective, cfg), tuning
    /// and inserting on a miss. The hit path takes one shard's read lock,
    /// performs one hash probe plus a streaming fingerprint confirmation,
    /// and allocates nothing beyond the returned `Arc` clone.
    pub fn get_or_tune(
        &self,
        cluster: &Cluster,
        placement: &Placement,
        collective: Collective,
        cfg: &TuneCfg,
    ) -> crate::Result<Arc<Decision>> {
        let digest = live_digest(cluster, placement, collective, cfg);
        let shard = self.shard_of(digest);
        if let Some(d) = probe_live(shard, digest, cluster, placement, collective, cfg) {
            shard.hits.fetch_add(1, Relaxed);
            return Ok(d);
        }
        shard.misses.fetch_add(1, Relaxed);

        // Miss: tune, warm-started from the nearest cached size class in
        // the same family when one exists. Seeding is ordering-only, so
        // the decision is bit-identical to a cold tune either way.
        let family = live_family_digest(cluster, placement, collective, cfg);
        let warm = self.warm_neighbor(family, cfg.msg_bytes);
        let decision = Arc::new(select_seeded(cluster, placement, collective, cfg, warm)?);
        if warm.is_some() {
            self.warm_hits.fetch_add(1, Relaxed);
        }
        let fp = Arc::new(Fingerprint::new(cluster, placement, collective, cfg));
        debug_assert_eq!(fp.digest(), digest, "live digest mirrors the constructed key");
        Ok(self.insert(shard, digest, family, fp, decision))
    }

    /// Direct probe without tuning on miss. Read lock only (shared
    /// probes run concurrently); counters move through relaxed atomics.
    pub fn lookup(&self, fp: &Fingerprint) -> Option<Arc<Decision>> {
        let digest = fp.digest();
        let shard = self.shard_of(digest);
        {
            let slots = shard.slots.read().expect("cache shard poisoned");
            if let Some(bucket) = slots.index.get(&digest) {
                for &slot in bucket {
                    let e = slots.slab[slot as usize].as_ref().expect("indexed slot is live");
                    if *e.fp == *fp {
                        e.referenced.store(true, Relaxed);
                        shard.hits.fetch_add(1, Relaxed);
                        return Some(Arc::clone(&e.decision));
                    }
                }
            }
        }
        shard.misses.fetch_add(1, Relaxed);
        None
    }

    /// Drop the cached decision for `fp` (online re-planning: a decision
    /// tuned for a topology that no longer exists must not be served).
    /// Returns whether an entry was actually removed. Hit/miss counters
    /// are untouched — invalidation is not a lookup.
    pub fn invalidate(&self, fp: &Fingerprint) -> bool {
        let digest = fp.digest();
        let shard = self.shard_of(digest);
        let removed = {
            let mut slots = shard.slots.write().expect("cache shard poisoned");
            let mut found = None;
            if let Some(bucket) = slots.index.get(&digest) {
                for &slot in bucket {
                    let e = slots.slab[slot as usize].as_ref().expect("indexed slot is live");
                    if *e.fp == *fp {
                        found = Some(slot);
                        break;
                    }
                }
            }
            found.map(|slot| slots.remove_slot(slot))
        };
        match removed {
            Some((family, digest)) => {
                shard.invalidations.fetch_add(1, Relaxed);
                self.warm_unlink(family, digest);
                true
            }
            None => false,
        }
    }

    /// Aggregate counters plus per-shard occupancy.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats {
            shards: self.shards.len(),
            warm_hits: self.warm_hits.load(Relaxed),
            per_shard: Vec::with_capacity(self.shards.len()),
            ..CacheStats::default()
        };
        for shard in &self.shards {
            s.hits += shard.hits.load(Relaxed);
            s.misses += shard.misses.load(Relaxed);
            s.invalidations += shard.invalidations.load(Relaxed);
            s.evictions += shard.evictions.load(Relaxed);
            let live = shard.slots.read().expect("cache shard poisoned").len();
            s.per_shard.push(live);
            s.entries += live;
        }
        s
    }

    /// Drop every entry and reset every counter (shard by shard, then
    /// the warm index).
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut slots = shard.slots.write().expect("cache shard poisoned");
            *slots = Slots::default();
            shard.hits.store(0, Relaxed);
            shard.misses.store(0, Relaxed);
            shard.invalidations.store(0, Relaxed);
            shard.evictions.store(0, Relaxed);
        }
        self.warm.write().expect("warm index poisoned").clear();
        self.warm_hits.store(0, Relaxed);
    }

    /// Insert under the shard's write lock, double-probing first: if a
    /// racing thread already tuned this fingerprint, adopt its entry
    /// (decisions are deterministic, so both copies are bit-identical).
    fn insert(
        &self,
        shard: &Shard,
        digest: u64,
        family: u64,
        fp: Arc<Fingerprint>,
        decision: Arc<Decision>,
    ) -> Arc<Decision> {
        let evicted;
        {
            let mut slots = shard.slots.write().expect("cache shard poisoned");
            if let Some(bucket) = slots.index.get(&digest) {
                for &slot in bucket {
                    let e = slots.slab[slot as usize].as_ref().expect("indexed slot is live");
                    if *e.fp == *fp {
                        e.referenced.store(true, Relaxed);
                        return Arc::clone(&e.decision);
                    }
                }
            }
            evicted = if slots.len() >= self.shard_cap { slots.evict_one() } else { None };
            slots.insert(Entry {
                digest,
                fp: Arc::clone(&fp),
                decision: Arc::clone(&decision),
                referenced: AtomicBool::new(false),
            });
        }
        if let Some((old_family, old_digest)) = evicted {
            shard.evictions.fetch_add(1, Relaxed);
            self.warm_unlink(old_family, old_digest);
        }
        self.warm_link(
            family,
            WarmEntry { digest, msg_bytes: fp.msg_bytes(), choice: decision.choice },
        );
        decision
    }

    /// The winning candidate of the nearest cached size class in this
    /// family (closest octave first, then closest byte count — fully
    /// deterministic), if one sits within [`WARM_CLASS_WINDOW`].
    fn warm_neighbor(&self, family: u64, msg_bytes: u64) -> Option<CandidateId> {
        let map = self.warm.read().expect("warm index poisoned");
        let class = size_class(msg_bytes);
        map.get(&family)?
            .iter()
            .filter(|e| e.msg_bytes != msg_bytes)
            .filter(|e| size_class(e.msg_bytes).abs_diff(class) <= WARM_CLASS_WINDOW)
            .min_by_key(|e| {
                (
                    size_class(e.msg_bytes).abs_diff(class),
                    e.msg_bytes.abs_diff(msg_bytes),
                    e.msg_bytes,
                )
            })
            .map(|e| e.choice)
    }

    fn warm_link(&self, family: u64, entry: WarmEntry) {
        let mut map = self.warm.write().expect("warm index poisoned");
        let bucket = map.entry(family).or_default();
        if !bucket.iter().any(|e| e.digest == entry.digest) {
            bucket.push(entry);
        }
    }

    fn warm_unlink(&self, family: u64, digest: u64) {
        let mut map = self.warm.write().expect("warm index poisoned");
        if let Some(bucket) = map.get_mut(&family) {
            if let Some(p) = bucket.iter().position(|e| e.digest == digest) {
                bucket.swap_remove(p);
            }
            if bucket.is_empty() {
                map.remove(&family);
            }
        }
    }
}

/// The hit path's shard probe: read lock, one hash probe, streaming
/// fingerprint confirmation against the live inputs — no `Fingerprint`
/// is ever built on a hit. Free function (not a method) so the borrow of
/// one shard is visibly independent of `&self`.
fn probe_live(
    shard: &Shard,
    digest: u64,
    cluster: &Cluster,
    placement: &Placement,
    collective: Collective,
    cfg: &TuneCfg,
) -> Option<Arc<Decision>> {
    let slots = shard.slots.read().expect("cache shard poisoned");
    for &slot in slots.index.get(&digest)? {
        let e = slots.slab[slot as usize].as_ref().expect("indexed slot is live");
        if e.fp.matches(cluster, placement, collective, cfg) {
            e.referenced.store(true, Relaxed);
            return Some(Arc::clone(&e.decision));
        }
    }
    None
}

/// Octave (power-of-two size class) of a byte count: 0 for 0 bytes,
/// else `floor(log2) + 1`.
fn size_class(bytes: u64) -> u32 {
    64 - bytes.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{switched, Placement};
    use crate::tune::select;

    #[test]
    fn second_lookup_hits_and_returns_identical_schedule() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let cache = DecisionCache::new();

        let first = cache
            .get_or_tune(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg)
            .unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.invalidations, s.entries), (0, 1, 0, 1));

        let second = cache
            .get_or_tune(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg)
            .unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.invalidations, s.entries), (1, 1, 0, 1));
        assert_eq!(first.schedule, second.schedule);
        // Interning: a hit clones the cached Arc, it does not copy the
        // decision.
        assert!(Arc::ptr_eq(&first, &second));
    }

    #[test]
    fn distinct_fingerprints_miss() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let cache = DecisionCache::new();
        cache.get_or_tune(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        // Different root: a different decision key.
        cache.get_or_tune(&cl, &pl, Collective::Broadcast { root: 3 }, &cfg).unwrap();
        // Different topology: another miss.
        let cl2 = switched(4, 4, 1);
        let pl2 = Placement::block(&cl2);
        cache.get_or_tune(&cl2, &pl2, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.invalidations, s.entries), (0, 3, 0, 3));
        assert_eq!(s.per_shard.len(), s.shards);
        assert_eq!(s.per_shard.iter().sum::<usize>(), s.entries);
    }

    #[test]
    fn lookup_is_shared_access_and_counts_misses() {
        let cl = switched(2, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let cache = DecisionCache::new();
        let fp = Fingerprint::new(&cl, &pl, Collective::Allgather, &cfg);
        assert!(cache.lookup(&fp).is_none());
        cache.get_or_tune(&cl, &pl, Collective::Allgather, &cfg).unwrap();
        assert!(cache.lookup(&fp).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
    }

    #[test]
    fn invalidate_removes_one_entry() {
        let cl = switched(3, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let cache = DecisionCache::new();
        cache.get_or_tune(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
        cache.get_or_tune(&cl, &pl, Collective::Allgather, &cfg).unwrap();
        let fp = Fingerprint::new(&cl, &pl, Collective::Allreduce, &cfg);
        assert!(cache.invalidate(&fp));
        assert!(!cache.invalidate(&fp), "second invalidation finds nothing");
        let s = cache.stats();
        assert_eq!(s.entries, 1, "only the invalidated entry is gone");
        assert_eq!(s.invalidations, 1, "no-op invalidation is not counted");
        // The next get_or_tune re-tunes (a miss), the untouched entry hits.
        cache.get_or_tune(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
        cache.get_or_tune(&cl, &pl, Collective::Allgather, &cfg).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.invalidations, s.entries), (1, 3, 1, 2));
    }

    #[test]
    fn clear_resets_everything() {
        let cl = switched(2, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let cache = DecisionCache::new();
        cache.get_or_tune(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
        cache
            .get_or_tune(&cl, &pl, Collective::Allreduce, &cfg.clone().with_msg_bytes(1 << 20))
            .unwrap();
        cache.clear();
        let s = cache.stats();
        assert_eq!(
            (s.hits, s.misses, s.invalidations, s.entries, s.evictions, s.warm_hits),
            (0, 0, 0, 0, 0, 0)
        );
        assert!(s.per_shard.iter().all(|&n| n == 0));
        // Cleared means cold: the same query misses (and re-tunes) again.
        cache.get_or_tune(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 1, 1));
    }

    #[test]
    fn capacity_bound_evicts_clock_second_chance() {
        // One shard, two slots: CLOCK must give a probed entry a second
        // chance and reclaim the cold one.
        let cl = switched(3, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let cache = DecisionCache::with_config(CacheConfig { shards: 1, capacity: 2 });
        let key = |root: usize| Fingerprint::new(&cl, &pl, Collective::Broadcast { root }, &cfg);

        let a = cache.get_or_tune(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        cache.get_or_tune(&cl, &pl, Collective::Broadcast { root: 1 }, &cfg).unwrap();
        // Touch A: its referenced bit marks it hot.
        let a2 = cache.get_or_tune(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        assert!(Arc::ptr_eq(&a, &a2));
        // Third insert: the hand clears A's bit (second chance) and
        // evicts cold B.
        cache.get_or_tune(&cl, &pl, Collective::Broadcast { root: 2 }, &cfg).unwrap();
        let s = cache.stats();
        assert_eq!((s.entries, s.evictions), (2, 1));
        assert!(cache.lookup(&key(0)).is_some(), "hot entry survives");
        assert!(cache.lookup(&key(2)).is_some(), "just-inserted entry survives");
        assert!(cache.lookup(&key(1)).is_none(), "cold entry was the victim");
    }

    #[test]
    fn eviction_never_evicts_the_entry_just_returned() {
        // Capacity one: every miss evicts — but never its own entry.
        let cl = switched(3, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let cache = DecisionCache::with_config(CacheConfig { shards: 1, capacity: 1 });
        for root in 0..4 {
            let d = cache.get_or_tune(&cl, &pl, Collective::Broadcast { root }, &cfg).unwrap();
            let fp = Fingerprint::new(&cl, &pl, Collective::Broadcast { root }, &cfg);
            let cached = cache.lookup(&fp).expect("just-returned entry is resident");
            assert!(Arc::ptr_eq(&d, &cached));
        }
        let s = cache.stats();
        assert_eq!((s.entries, s.evictions, s.misses), (1, 3, 4));
    }

    #[test]
    fn warm_start_seeds_from_neighbor_size_class_bit_identically() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let coll = Collective::Broadcast { root: 0 };
        let small = TuneCfg::default().with_msg_bytes(4 << 10);
        let large = TuneCfg::default().with_msg_bytes(16 << 10);

        let cache = DecisionCache::new();
        cache.get_or_tune(&cl, &pl, coll, &small).unwrap();
        assert_eq!(cache.stats().warm_hits, 0, "first tune in a family is cold");
        let warm = cache.get_or_tune(&cl, &pl, coll, &large).unwrap();
        assert_eq!(cache.stats().warm_hits, 1, "neighbor size class seeds the tune");

        // The differential guarantee, end to end: the warm-started pick
        // is bit-identical to a cold tune of the same query.
        let cold = select(&cl, &pl, coll, &large).unwrap();
        assert_eq!(warm.choice, cold.choice);
        assert_eq!(warm.schedule, cold.schedule);
        assert_eq!(warm.model_cost.to_bits(), cold.model_cost.to_bits());
        assert_eq!(warm.sim_time.to_bits(), cold.sim_time.to_bits());
        assert_eq!(warm.baseline_sim, cold.baseline_sim);
        assert_eq!((warm.considered, warm.simulated), (cold.considered, cold.simulated));

        // Invalidation unlinks the warm index too: with the only family
        // neighbor gone, the next miss tunes cold.
        let small_fp = Fingerprint::new(&cl, &pl, coll, &small);
        let large_fp = Fingerprint::new(&cl, &pl, coll, &large);
        assert!(cache.invalidate(&small_fp));
        assert!(cache.invalidate(&large_fp));
        cache.get_or_tune(&cl, &pl, coll, &small).unwrap();
        assert_eq!(cache.stats().warm_hits, 1, "no neighbors left: cold tune");
    }

    #[test]
    fn warm_neighbor_prefers_nearest_octave() {
        let cache = DecisionCache::new();
        let mk = |digest, msg_bytes, choice| WarmEntry { digest, msg_bytes, choice };
        let flat = CandidateId::BcastBinomial { root: 0 };
        let near = CandidateId::BcastFlatTree { root: 0 };
        cache.warm_link(7, mk(1, 1 << 10, flat));
        cache.warm_link(7, mk(2, 1 << 13, near));
        // Query at 16 KiB: 8 KiB (1 octave) beats 1 KiB (4 octaves).
        assert_eq!(cache.warm_neighbor(7, 1 << 14), Some(near));
        // Outside the octave window, or the wrong family: no seed.
        assert_eq!(cache.warm_neighbor(7, 1 << 30), None);
        assert_eq!(cache.warm_neighbor(8, 1 << 14), None);
        // Exact size is not a "neighbor" (that would have been a hit).
        cache.warm_unlink(7, 1);
        assert_eq!(cache.warm_neighbor(7, 1 << 13), None);
    }
}
