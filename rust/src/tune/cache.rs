//! Decision cache: fingerprint → [`Decision`], so repeated lookups skip
//! candidate construction and simulation entirely.
//!
//! A hit returns the cached decision — including the schedule, whose rank
//! numbering is valid because equal fingerprints imply the exact same
//! cluster + placement (see [`super::fingerprint`]). The per-lookup work
//! on a hit is computing the fingerprint (linear in the topology
//! description, microseconds) plus one hash-map probe; no schedules are
//! built and nothing is simulated.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use crate::topology::{Cluster, Placement};

use super::fingerprint::Fingerprint;
use super::registry::Collective;
use super::selector::{select, Decision, TuneCfg};

/// Hit/miss/invalidation counters for observability (E9 benches, the
/// trainer's end-of-run report, tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    /// Entries actually removed by [`DecisionCache::invalidate`] (calls
    /// that found nothing to remove are not counted).
    pub invalidations: usize,
    pub entries: usize,
}

/// An in-memory decision cache. Single-threaded by itself; wrap in the
/// thread-safe [`crate::tune::Tuned`] facade for shared use.
#[derive(Debug, Default)]
pub struct DecisionCache {
    map: HashMap<Fingerprint, Decision>,
    hits: usize,
    misses: usize,
    invalidations: usize,
}

impl DecisionCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up the decision for this (topology, collective, cfg), tuning
    /// and inserting on a miss.
    pub fn get_or_tune(
        &mut self,
        cluster: &Cluster,
        placement: &Placement,
        collective: Collective,
        cfg: &TuneCfg,
    ) -> crate::Result<&Decision> {
        let fp = Fingerprint::new(cluster, placement, collective, cfg);
        match self.map.entry(fp) {
            Entry::Occupied(hit) => {
                self.hits += 1;
                Ok(hit.into_mut())
            }
            Entry::Vacant(slot) => {
                self.misses += 1;
                let decision = select(cluster, placement, collective, cfg)?;
                Ok(slot.insert(decision))
            }
        }
    }

    /// Direct probe without tuning on miss.
    pub fn lookup(&mut self, fp: &Fingerprint) -> Option<&Decision> {
        match self.map.get(fp) {
            Some(decision) => {
                self.hits += 1;
                Some(decision)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Drop the cached decision for `fp` (online re-planning: a decision
    /// tuned for a topology that no longer exists must not be served).
    /// Returns whether an entry was actually removed. Hit/miss counters
    /// are untouched — invalidation is not a lookup.
    pub fn invalidate(&mut self, fp: &Fingerprint) -> bool {
        let removed = self.map.remove(fp).is_some();
        if removed {
            self.invalidations += 1;
        }
        removed
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            invalidations: self.invalidations,
            entries: self.map.len(),
        }
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.hits = 0;
        self.misses = 0;
        self.invalidations = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{switched, Placement};

    #[test]
    fn second_lookup_hits_and_returns_identical_schedule() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let mut cache = DecisionCache::new();

        let first = cache
            .get_or_tune(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg)
            .unwrap()
            .schedule
            .clone();
        assert_eq!(
            cache.stats(),
            CacheStats { hits: 0, misses: 1, invalidations: 0, entries: 1 }
        );

        let second = cache
            .get_or_tune(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg)
            .unwrap()
            .schedule
            .clone();
        assert_eq!(
            cache.stats(),
            CacheStats { hits: 1, misses: 1, invalidations: 0, entries: 1 }
        );
        assert_eq!(first, second);
    }

    #[test]
    fn distinct_fingerprints_miss() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let mut cache = DecisionCache::new();
        cache.get_or_tune(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        // Different root: a different decision key.
        cache.get_or_tune(&cl, &pl, Collective::Broadcast { root: 3 }, &cfg).unwrap();
        // Different topology: another miss.
        let cl2 = switched(4, 4, 1);
        let pl2 = Placement::block(&cl2);
        cache.get_or_tune(&cl2, &pl2, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        assert_eq!(
            cache.stats(),
            CacheStats { hits: 0, misses: 3, invalidations: 0, entries: 3 }
        );
    }

    #[test]
    fn lookup_counts_misses_without_tuning() {
        let cl = switched(2, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let mut cache = DecisionCache::new();
        let fp = Fingerprint::new(&cl, &pl, Collective::Allgather, &cfg);
        assert!(cache.lookup(&fp).is_none());
        cache.get_or_tune(&cl, &pl, Collective::Allgather, &cfg).unwrap();
        assert!(cache.lookup(&fp).is_some());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
    }

    #[test]
    fn invalidate_removes_one_entry() {
        let cl = switched(3, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let mut cache = DecisionCache::new();
        cache.get_or_tune(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
        cache.get_or_tune(&cl, &pl, Collective::Allgather, &cfg).unwrap();
        let fp = Fingerprint::new(&cl, &pl, Collective::Allreduce, &cfg);
        assert!(cache.invalidate(&fp));
        assert!(!cache.invalidate(&fp), "second invalidation finds nothing");
        let s = cache.stats();
        assert_eq!(s.entries, 1, "only the invalidated entry is gone");
        assert_eq!(s.invalidations, 1, "no-op invalidation is not counted");
        // The next get_or_tune re-tunes (a miss), the untouched entry hits.
        cache.get_or_tune(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
        cache.get_or_tune(&cl, &pl, Collective::Allgather, &cfg).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.invalidations, s.entries), (1, 3, 1, 2));
    }

    #[test]
    fn clear_resets_everything() {
        let cl = switched(2, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let mut cache = DecisionCache::new();
        cache.get_or_tune(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
        cache.clear();
        assert_eq!(cache.stats(), CacheStats::default());
    }
}
