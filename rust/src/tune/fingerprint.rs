//! Canonical topology fingerprints — the decision-cache key.
//!
//! A [`Fingerprint`] captures everything the tuner's decision depends on:
//! the cluster (machine specs + interconnect), the placement, the
//! requested collective (including its root), the payload size class
//! (`TuneCfg::msg_bytes` — algorithm choice is message-size-dependent,
//! so a 1 KB and a 1 GB request must tune independently), and the
//! evaluation parameters (duplex assumption, `alpha`, byte weights,
//! simulator physics). Two lookups with equal fingerprints are
//! guaranteed to want the same schedule, so the cached
//! [`crate::tune::Decision`] — rank numbers and all — can be reused
//! verbatim.
//!
//! **Canonical** here means *normalized representation* plus the one
//! isomorphism we can quotient for free: floats are compared
//! bit-exactly, graph adjacency is folded to a sorted undirected edge
//! list (so the same graph described in any order, with duplicate or
//! one-sided edges, fingerprints identically —
//! [`crate::topology::Cluster::new`] performs the normalization), a
//! switch is a flag rather than a clique, and on a
//! [`crate::topology::SymmetryClass::Uniform`] cluster the placement
//! map is relabeled into machine first-appearance order. Every machine
//! of a uniform switched grid is interchangeable, so machine-permuted
//! but otherwise identical placements share one cache entry — the
//! cached schedule is rank-indexed, its co-location structure is the
//! same under both placements, and with uniform machines it is valid
//! and identically priced on either. Locality still discriminates
//! (block and round-robin maps stay distinct under first-appearance
//! relabeling), and the quotient is skipped whenever machine identity
//! carries physics — injected per-machine slowdowns or robustness
//! draws ([`crate::tune::Robustness`]) pin real machine indices, so
//! those configurations fingerprint verbatim. `Irregular` clusters
//! always fingerprint verbatim too: full canonical labeling is
//! graph-isomorphism-hard, and being conservative is always sound
//! because a cached schedule's rank numbering only fits the exact
//! topology it was tuned for.

use std::cell::RefCell;

use crate::sim::SimParams;
use crate::topology::{Cluster, Interconnect, Placement};
use crate::tune::{Collective, TuneCfg};

thread_local! {
    /// Reusable machine-relabeling scratch for the allocation-free
    /// fingerprint walks ([`live_digest`], [`Fingerprint::matches`]):
    /// grown once per thread, then reused — the concurrent cache's hit
    /// path does zero heap allocation.
    static RELABEL: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
}

/// Hashable, equality-comparable key for one tuning decision.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Fingerprint {
    /// Per machine, in machine order: (cores, nics, speed bits).
    machines: Vec<(usize, usize, u64)>,
    /// Sorted undirected edge list; empty for a full switch.
    edges: Vec<(usize, usize)>,
    /// Non-blocking switch (edge list irrelevant) vs. explicit graph.
    switch: bool,
    /// Placement map: rank -> machine, relabeled into first-appearance
    /// order on uniform clusters with machine-symmetric physics (see the
    /// module docs).
    machine_of: Vec<usize>,
    /// The requested operation, root included.
    collective: Collective,
    /// Total payload bytes the decision is tuned for (size class): a
    /// small and a large request must never alias.
    msg_bytes: u64,
    /// Model knobs: half-duplex NICs, the internal-work weight, and the
    /// serialized-byte weights.
    duplex_half: bool,
    alpha_bits: u64,
    byte_ext_bits: u64,
    byte_int_bits: u64,
    /// Digest of the simulator physics (`record_xfers` excluded: it never
    /// changes timing).
    sim_bits: u64,
    /// Stage-2 pool width — decides which candidates get simulated, so
    /// decisions made under different widths must not alias.
    shortlist: usize,
    /// Digest of the machine profile the configuration was calibrated
    /// from (0 = defaults) — recalibrating invalidates cached decisions.
    profile: u64,
    /// Robustness knob: (straggler draws, draw seed, factor bits). A
    /// clean tune (draws = 0) and a robust tune must never alias.
    robustness: (usize, u64, u64),
    /// Quotient knobs: fast path on/off and the materialization cap.
    /// Above the cap the cached decision carries no schedule, so
    /// configurations with different caps must never alias.
    quotient: (bool, usize),
}

impl Fingerprint {
    pub fn new(
        cluster: &Cluster,
        placement: &Placement,
        collective: Collective,
        cfg: &TuneCfg,
    ) -> Self {
        let machines = cluster
            .machines
            .iter()
            .map(|m| (m.cores, m.nics, m.speed.to_bits()))
            .collect();
        let (switch, edges) = match &cluster.interconnect {
            Interconnect::FullSwitch => (true, Vec::new()),
            Interconnect::Graph { adj } => {
                let mut edges = Vec::new();
                for (a, row) in adj.iter().enumerate() {
                    for &b in row {
                        if a < b {
                            edges.push((a, b));
                        }
                    }
                }
                edges.sort_unstable();
                (false, edges)
            }
        };
        let mut machine_of: Vec<usize> = (0..placement.num_ranks())
            .map(|r| placement.machine_of(r))
            .collect();
        // Machine-relabeling quotient: on a uniform cluster every machine
        // is interchangeable, so fold the placement into first-appearance
        // order — unless machine identity carries physics (injected
        // per-machine slowdowns, robustness draws), in which case the
        // verbatim map is the sound key.
        let symmetric_physics =
            cfg.sim.slowdown.is_empty() && cfg.robustness.draws == 0;
        if symmetric_physics
            && matches!(
                cluster.symmetry,
                crate::topology::SymmetryClass::Uniform { .. }
            )
        {
            let mut relabel = vec![usize::MAX; cluster.num_machines()];
            let mut next = 0usize;
            for m in machine_of.iter_mut() {
                if relabel[*m] == usize::MAX {
                    relabel[*m] = next;
                    next += 1;
                }
                *m = relabel[*m];
            }
        }
        Self {
            machines,
            edges,
            switch,
            machine_of,
            collective,
            msg_bytes: cfg.msg_bytes,
            duplex_half: matches!(cfg.model.duplex, crate::model::Duplex::Half),
            alpha_bits: cfg.model.alpha.to_bits(),
            byte_ext_bits: cfg.model.byte_ext.to_bits(),
            byte_int_bits: cfg.model.byte_int.to_bits(),
            sim_bits: sim_digest(&cfg.sim),
            shortlist: cfg.shortlist,
            profile: cfg.profile_digest,
            robustness: (
                cfg.robustness.draws,
                cfg.robustness.seed,
                cfg.robustness.factor.to_bits(),
            ),
            quotient: (cfg.quotient, cfg.quotient_sim_cap),
        }
    }

    /// Short stable digest for logs and reports (FNV-1a over the full
    /// key). Collisions here are cosmetic; the cache compares full keys.
    pub fn digest(&self) -> u64 {
        self.fold(true)
    }

    /// Family digest: [`Fingerprint::digest`] with the payload size class
    /// (`msg_bytes`) left out of the fold. Two fingerprints share a family
    /// exactly when they differ *only* by message size — same canonical
    /// topology, placement, collective (root included), and every model /
    /// simulator / robustness / quotient knob. The warm-start index in
    /// [`crate::tune::DecisionCache`] buckets entries by this digest so a
    /// miss can borrow the winner from an adjacent size class.
    pub fn family_digest(&self) -> u64 {
        self.fold(false)
    }

    /// The payload size class this decision was tuned for.
    pub fn msg_bytes(&self) -> u64 {
        self.msg_bytes
    }

    fn fold(&self, include_msg: bool) -> u64 {
        let mut h = FNV_OFFSET;
        for &(c, n, s) in &self.machines {
            h = fnv(h, c as u64);
            h = fnv(h, n as u64);
            h = fnv(h, s);
        }
        for &(a, b) in &self.edges {
            h = fnv(h, a as u64);
            h = fnv(h, b as u64);
        }
        h = fnv(h, self.switch as u64);
        for &m in &self.machine_of {
            h = fnv(h, m as u64);
        }
        h = fnv(h, collective_tag(self.collective));
        if include_msg {
            h = fnv(h, self.msg_bytes);
        }
        h = fnv(h, self.duplex_half as u64);
        h = fnv(h, self.alpha_bits);
        h = fnv(h, self.byte_ext_bits);
        h = fnv(h, self.byte_int_bits);
        h = fnv(h, self.sim_bits);
        h = fnv(h, self.shortlist as u64);
        h = fnv(h, self.profile);
        h = fnv(h, self.robustness.0 as u64);
        h = fnv(h, self.robustness.1);
        h = fnv(h, self.robustness.2);
        h = fnv(h, self.quotient.0 as u64);
        h = fnv(h, self.quotient.1 as u64);
        h
    }

    /// Allocation-free equality against *live* tuning inputs: exactly
    /// `self == &Fingerprint::new(cluster, placement, collective, cfg)`
    /// without constructing the right-hand side. The concurrent cache's
    /// hit path digests the live inputs with [`live_digest`], probes one
    /// shard, and confirms the colliding entry with this walk — one hash
    /// probe, zero allocation.
    pub fn matches(
        &self,
        cluster: &Cluster,
        placement: &Placement,
        collective: Collective,
        cfg: &TuneCfg,
    ) -> bool {
        // Cheap scalar knobs first: almost every mismatch dies here.
        if self.collective != collective
            || self.msg_bytes != cfg.msg_bytes
            || self.duplex_half
                != matches!(cfg.model.duplex, crate::model::Duplex::Half)
            || self.alpha_bits != cfg.model.alpha.to_bits()
            || self.byte_ext_bits != cfg.model.byte_ext.to_bits()
            || self.byte_int_bits != cfg.model.byte_int.to_bits()
            || self.shortlist != cfg.shortlist
            || self.profile != cfg.profile_digest
            || self.robustness
                != (
                    cfg.robustness.draws,
                    cfg.robustness.seed,
                    cfg.robustness.factor.to_bits(),
                )
            || self.quotient != (cfg.quotient, cfg.quotient_sim_cap)
            || self.sim_bits != sim_digest(&cfg.sim)
        {
            return false;
        }
        // Machine specs, in machine order.
        if self.machines.len() != cluster.num_machines() {
            return false;
        }
        for (&(c, n, s), m) in self.machines.iter().zip(&cluster.machines) {
            if c != m.cores || n != m.nics || s != m.speed.to_bits() {
                return false;
            }
        }
        // Interconnect: Cluster::new normalizes adjacency (sorted rows,
        // deduped, symmetric), so the (a asc, b in row asc, a < b) walk
        // streams the canonical sorted edge list directly.
        match &cluster.interconnect {
            Interconnect::FullSwitch => {
                if !self.switch {
                    return false;
                }
            }
            Interconnect::Graph { adj } => {
                if self.switch {
                    return false;
                }
                let mut want = self.edges.iter();
                for (a, row) in adj.iter().enumerate() {
                    for &b in row {
                        if a < b {
                            match want.next() {
                                Some(&(x, y)) if x == a && y == b => {}
                                _ => return false,
                            }
                        }
                    }
                }
                if want.next().is_some() {
                    return false;
                }
            }
        }
        // Placement, replaying the machine-relabeling quotient when it
        // applies (thread-local scratch; no allocation once warm).
        if self.machine_of.len() != placement.num_ranks() {
            return false;
        }
        if relabels(cluster, cfg) {
            with_relabel(cluster.num_machines(), |relabel| {
                let mut next = 0usize;
                for (r, &want) in self.machine_of.iter().enumerate() {
                    let m = placement.machine_of(r);
                    if relabel[m] == usize::MAX {
                        relabel[m] = next;
                        next += 1;
                    }
                    if relabel[m] != want {
                        return false;
                    }
                }
                true
            })
        } else {
            self.machine_of
                .iter()
                .enumerate()
                .all(|(r, &m)| m == placement.machine_of(r))
        }
    }
}

/// Does the machine-relabeling quotient apply to this (cluster, cfg)
/// pair? Mirrors the condition in [`Fingerprint::new`] exactly.
fn relabels(cluster: &Cluster, cfg: &TuneCfg) -> bool {
    cfg.sim.slowdown.is_empty()
        && cfg.robustness.draws == 0
        && matches!(
            cluster.symmetry,
            crate::topology::SymmetryClass::Uniform { .. }
        )
}

/// Run `f` with a `usize::MAX`-filled relabel table of length `n`,
/// reusing a thread-local scratch vector (zero allocation once warm).
fn with_relabel<R>(n: usize, f: impl FnOnce(&mut [usize]) -> R) -> R {
    RELABEL.with(|cell| {
        let mut v = cell.borrow_mut();
        v.clear();
        v.resize(n, usize::MAX);
        f(&mut v)
    })
}

/// Digest the live tuning inputs without building a [`Fingerprint`]:
/// bit-identical to `Fingerprint::new(...).digest()`, but allocation-free
/// (the machine-relabeling quotient runs on a thread-local scratch). The
/// concurrent decision cache uses this to pick a shard and probe it on
/// the hit path.
pub fn live_digest(
    cluster: &Cluster,
    placement: &Placement,
    collective: Collective,
    cfg: &TuneCfg,
) -> u64 {
    live_fold(cluster, placement, collective, cfg, true)
}

/// Family sibling of [`live_digest`]: bit-identical to
/// `Fingerprint::new(...).family_digest()` without the allocation.
pub fn live_family_digest(
    cluster: &Cluster,
    placement: &Placement,
    collective: Collective,
    cfg: &TuneCfg,
) -> u64 {
    live_fold(cluster, placement, collective, cfg, false)
}

fn live_fold(
    cluster: &Cluster,
    placement: &Placement,
    collective: Collective,
    cfg: &TuneCfg,
    include_msg: bool,
) -> u64 {
    let mut h = FNV_OFFSET;
    for m in &cluster.machines {
        h = fnv(h, m.cores as u64);
        h = fnv(h, m.nics as u64);
        h = fnv(h, m.speed.to_bits());
    }
    let switch = match &cluster.interconnect {
        Interconnect::FullSwitch => true,
        Interconnect::Graph { adj } => {
            // Normalized adjacency streams the sorted edge list (see
            // `Fingerprint::matches`).
            for (a, row) in adj.iter().enumerate() {
                for &b in row {
                    if a < b {
                        h = fnv(h, a as u64);
                        h = fnv(h, b as u64);
                    }
                }
            }
            false
        }
    };
    h = fnv(h, switch as u64);
    let num_ranks = placement.num_ranks();
    if relabels(cluster, cfg) {
        h = with_relabel(cluster.num_machines(), |relabel| {
            let mut h = h;
            let mut next = 0usize;
            for r in 0..num_ranks {
                let m = placement.machine_of(r);
                if relabel[m] == usize::MAX {
                    relabel[m] = next;
                    next += 1;
                }
                h = fnv(h, relabel[m] as u64);
            }
            h
        });
    } else {
        for r in 0..num_ranks {
            h = fnv(h, placement.machine_of(r) as u64);
        }
    }
    h = fnv(h, collective_tag(collective));
    if include_msg {
        h = fnv(h, cfg.msg_bytes);
    }
    h = fnv(
        h,
        matches!(cfg.model.duplex, crate::model::Duplex::Half) as u64,
    );
    h = fnv(h, cfg.model.alpha.to_bits());
    h = fnv(h, cfg.model.byte_ext.to_bits());
    h = fnv(h, cfg.model.byte_int.to_bits());
    h = fnv(h, sim_digest(&cfg.sim));
    h = fnv(h, cfg.shortlist as u64);
    h = fnv(h, cfg.profile_digest);
    h = fnv(h, cfg.robustness.draws as u64);
    h = fnv(h, cfg.robustness.seed);
    h = fnv(h, cfg.robustness.factor.to_bits());
    h = fnv(h, cfg.quotient as u64);
    h = fnv(h, cfg.quotient_sim_cap as u64);
    h
}

/// FNV-1a offset basis — start value for every digest in the crate.
pub(crate) const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// FNV-1a fold step, shared by the fingerprint/schedule digests here and
/// [`crate::calibrate::MachineProfile::digest`].
pub(crate) fn fnv(acc: u64, word: u64) -> u64 {
    (acc ^ word).wrapping_mul(0x100000001b3)
}

/// FNV-1a digest of a schedule's complete structure: op (root/chunks
/// included), rank count, algorithm label, and every transfer's kind,
/// endpoints and payload (chunk ids + contribution members) in round
/// order.
///
/// This is the executor-side sibling of [`Fingerprint::digest`]: the
/// [`crate::coordinator::Communicator`] buckets its compiled
/// [`crate::exec::ExecPlan`]s by this digest and compares full schedules
/// on probe, so a cache hit skips symbolic re-validation and plan
/// extraction while collisions stay harmless.
pub fn schedule_digest(s: &crate::sched::Schedule) -> u64 {
    use crate::sched::{CollectiveOp, XferKind};
    let mut h = FNV_OFFSET;
    let op_word = match s.op {
        CollectiveOp::Broadcast { root } => 1u64 << 56 | root as u64,
        CollectiveOp::Gather { root } => 2u64 << 56 | root as u64,
        CollectiveOp::Scatter { root } => 3u64 << 56 | root as u64,
        CollectiveOp::Allgather => 4u64 << 56,
        CollectiveOp::AllToAll => 5u64 << 56,
        CollectiveOp::Reduce { root, chunks } => {
            6u64 << 56 | (chunks as u64) << 32 | root as u64
        }
        CollectiveOp::Allreduce { chunks } => 7u64 << 56 | chunks as u64,
        CollectiveOp::ReduceScatter => 8u64 << 56,
    };
    h = fnv(h, op_word);
    h = fnv(h, s.num_ranks as u64);
    // Payload sizing is part of the schedule's identity: the same round
    // structure at a different size (or segmentation) prices and executes
    // differently.
    h = fnv(h, s.msg.total_bytes);
    h = fnv(h, s.msg.chunks as u64);
    h = fnv(h, s.msg.segments as u64);
    h = fnv(h, s.msg.elem_bytes);
    for &b in s.algo.as_bytes() {
        h = fnv(h, b as u64);
    }
    for round in &s.rounds {
        h = fnv(h, u64::MAX); // round boundary
        for x in &round.xfers {
            h = fnv(
                h,
                match x.kind {
                    XferKind::External => 1,
                    XferKind::LocalWrite => 2,
                    XferKind::LocalRead => 3,
                },
            );
            h = fnv(h, x.src as u64);
            h = fnv(h, x.dsts.len() as u64);
            for &d in &x.dsts {
                h = fnv(h, d as u64);
            }
            h = fnv(h, x.payload.items.len() as u64);
            for (c, contrib) in &x.payload.items {
                h = fnv(h, c.0 as u64);
                h = fnv(h, contrib.len() as u64);
                for r in contrib.iter() {
                    h = fnv(h, r as u64);
                }
            }
        }
    }
    h
}

fn collective_tag(c: Collective) -> u64 {
    match c {
        Collective::Broadcast { root } => 1 << 56 | root as u64,
        Collective::Gather { root } => 2 << 56 | root as u64,
        Collective::Scatter { root } => 3 << 56 | root as u64,
        Collective::Reduce { root } => 4 << 56 | root as u64,
        Collective::Allgather => 5 << 56,
        Collective::AllToAll => 6 << 56,
        Collective::Allreduce => 7 << 56,
        Collective::ReduceScatter => 8 << 56,
    }
}

fn sim_digest(p: &SimParams) -> u64 {
    let mut h = FNV_OFFSET;
    for bits in [
        p.o_send.to_bits(),
        p.o_recv.to_bits(),
        p.o_write.to_bits(),
        p.gap.to_bits(),
        p.lat_ext.to_bits(),
        p.lat_int.to_bits(),
        p.byte_time_ext.to_bits(),
        p.byte_time_int.to_bits(),
        p.nic_limited as u64,
        p.respect_speed as u64,
    ] {
        h = fnv(h, bits);
    }
    // Injected faults are physics too: a straggler-loaded or death-loaded
    // parameter set must not alias the healthy one.
    h = fnv(h, p.slowdown.len() as u64);
    for &(m, f) in &p.slowdown {
        h = fnv(h, m as u64);
        h = fnv(h, f.to_bits());
    }
    h = fnv(h, p.dead_ranks.len() as u64);
    for &(r, rd) in &p.dead_ranks {
        h = fnv(h, r as u64);
        h = fnv(h, rd as u64);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Duplex, Multicore};
    use crate::topology::{switched, Interconnect, MachineSpec};

    fn fp(cluster: &Cluster, cfg: &TuneCfg) -> Fingerprint {
        let placement = Placement::block(cluster);
        Fingerprint::new(cluster, &placement, Collective::Broadcast { root: 0 }, cfg)
    }

    #[test]
    fn identical_inputs_fingerprint_identically() {
        let cfg = TuneCfg::default();
        let a = fp(&switched(3, 4, 2), &cfg);
        let b = fp(&switched(3, 4, 2), &cfg);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn adjacency_representation_is_canonicalized() {
        // The same triangle described shuffled, duplicated and one-sided:
        // Cluster::new normalizes, so fingerprints agree.
        let cfg = TuneCfg::default();
        let machines = vec![MachineSpec::new(2, 1); 3];
        let a = Cluster::new(
            machines.clone(),
            Interconnect::Graph { adj: vec![vec![2, 1], vec![0, 2], vec![1, 0]] },
        )
        .unwrap();
        let b = Cluster::new(
            machines,
            Interconnect::Graph { adj: vec![vec![1, 1, 2], vec![2], vec![]] },
        )
        .unwrap();
        assert_eq!(fp(&a, &cfg), fp(&b, &cfg));
    }

    #[test]
    fn every_ingredient_discriminates() {
        let cfg = TuneCfg::default();
        let base = fp(&switched(3, 4, 2), &cfg);

        // Topology shape.
        assert_ne!(base, fp(&switched(3, 4, 1), &cfg)); // nics
        assert_ne!(base, fp(&switched(3, 2, 2), &cfg)); // cores
        assert_ne!(base, fp(&switched(4, 4, 2), &cfg)); // machines

        // Root.
        let cl = switched(3, 4, 2);
        let pl = Placement::block(&cl);
        let r0 = Fingerprint::new(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg);
        let r1 = Fingerprint::new(&cl, &pl, Collective::Broadcast { root: 1 }, &cfg);
        assert_ne!(r0, r1);

        // Op kind.
        let g = Fingerprint::new(&cl, &pl, Collective::Gather { root: 0 }, &cfg);
        assert_ne!(r0, g);

        // Model knobs.
        let mut half = TuneCfg::default();
        half.model = Multicore { duplex: Duplex::Half, ..Multicore::default() };
        assert_ne!(base, fp(&switched(3, 4, 2), &half));
        let mut alpha = TuneCfg::default();
        alpha.model = Multicore { alpha: 0.2, ..Multicore::default() };
        assert_ne!(base, fp(&switched(3, 4, 2), &alpha));
        let mut bytes_w = TuneCfg::default();
        bytes_w.model = Multicore { byte_ext: 0.0, ..Multicore::default() };
        assert_ne!(base, fp(&switched(3, 4, 2), &bytes_w));

        // Payload size class: a 1 KB and a 1 GB request never alias.
        let sized = TuneCfg::default().with_msg_bytes(1 << 30);
        assert_ne!(base, fp(&switched(3, 4, 2), &sized));

        // Simulator physics.
        let mut sim = TuneCfg::default();
        sim.sim.lat_ext = 10e-6;
        assert_ne!(base, fp(&switched(3, 4, 2), &sim));

        // Injected faults are physics too.
        let mut strag = TuneCfg::default();
        strag.sim = strag.sim.with_slowdown(1, 4.0);
        assert_ne!(base, fp(&switched(3, 4, 2), &strag));
        let mut death = TuneCfg::default();
        death.sim = death.sim.with_dead_rank(2, 1);
        assert_ne!(base, fp(&switched(3, 4, 2), &death));
        let mut deaths2 = TuneCfg::default();
        deaths2.sim = deaths2.sim.with_dead_rank(2, 1).with_dead_rank(5, 0);
        assert_ne!(base, fp(&switched(3, 4, 2), &deaths2));
        let fp_d1 = fp(&switched(3, 4, 2), &death);
        assert_ne!(fp_d1, fp(&switched(3, 4, 2), &deaths2));

        // Robustness knob: clean and robust tunes never alias, and each
        // ingredient of the knob discriminates.
        let robust = TuneCfg::default().with_robustness(4, 7, 8.0);
        let fp_robust = fp(&switched(3, 4, 2), &robust);
        assert_ne!(base, fp_robust);
        assert_ne!(base.digest(), fp_robust.digest());
        for other in [(5, 7, 8.0), (4, 8, 8.0), (4, 7, 2.0)] {
            let cfg2 = TuneCfg::default().with_robustness(other.0, other.1, other.2);
            assert_ne!(fp_robust, fp(&switched(3, 4, 2), &cfg2), "{other:?}");
        }

        // Stage-2 pool width (decides what gets simulated).
        let mut wide = TuneCfg::default();
        wide.shortlist = usize::MAX;
        assert_ne!(base, fp(&switched(3, 4, 2), &wide));

        // Quotient knobs: a fast-path and a full-materialization tune
        // may carry different decisions (schedule presence), as may two
        // different materialization caps.
        let off = TuneCfg::default().with_quotient(false);
        assert_ne!(base, fp(&switched(3, 4, 2), &off));
        let mut capped = TuneCfg::default();
        capped.quotient_sim_cap = 64;
        assert_ne!(base, fp(&switched(3, 4, 2), &capped));

        // Machine-profile provenance: identical model/sim knobs but a
        // different calibration digest must not alias (recalibration
        // invalidates cached decisions).
        let mut recal = TuneCfg::default();
        recal.profile_digest = 0xDEADBEEF;
        let fp_recal = fp(&switched(3, 4, 2), &recal);
        assert_ne!(base, fp_recal);
        assert_ne!(base.digest(), fp_recal.digest());
    }

    #[test]
    fn uniform_machine_relabeling_aliases() {
        // Machine-permuted but otherwise identical placements on a
        // uniform grid are one fingerprint — and one cache entry.
        let cl = switched(3, 2, 1);
        let cfg = TuneCfg::default();
        let coll = Collective::Allreduce;
        let block = Placement::block(&cl); // machines [0,0,1,1,2,2]
        let perm = Placement::explicit(&cl, vec![2, 2, 0, 0, 1, 1]).unwrap();
        let a = Fingerprint::new(&cl, &block, coll, &cfg);
        let b = Fingerprint::new(&cl, &perm, coll, &cfg);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());

        let cache = crate::tune::DecisionCache::new();
        cache.get_or_tune(&cl, &block, coll, &cfg).unwrap();
        cache.get_or_tune(&cl, &perm, coll, &cfg).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));

        // Machine-asymmetric physics pin real machine indices: no
        // relabeling under an injected straggler or robustness draws.
        let mut strag = TuneCfg::default();
        strag.sim = strag.sim.with_slowdown(0, 4.0);
        assert_ne!(
            Fingerprint::new(&cl, &block, coll, &strag),
            Fingerprint::new(&cl, &perm, coll, &strag)
        );
        let robust = TuneCfg::default().with_robustness(2, 9, 8.0);
        assert_ne!(
            Fingerprint::new(&cl, &block, coll, &robust),
            Fingerprint::new(&cl, &perm, coll, &robust)
        );

        // Irregular clusters never relabel: the same permutation on a
        // line topology keeps its verbatim (distinct) key.
        let line = crate::topology::line(3, 2, 1);
        let lb = Placement::block(&line);
        let lp = Placement::explicit(&line, vec![2, 2, 0, 0, 1, 1]).unwrap();
        assert_ne!(
            Fingerprint::new(&line, &lb, coll, &cfg),
            Fingerprint::new(&line, &lp, coll, &cfg)
        );
    }

    #[test]
    fn placement_discriminates() {
        let cl = switched(2, 2, 1);
        let cfg = TuneCfg::default();
        let block = Placement::block(&cl);
        let rr = Placement::round_robin(&cl);
        let a = Fingerprint::new(&cl, &block, Collective::Allgather, &cfg);
        let b = Fingerprint::new(&cl, &rr, Collective::Allgather, &cfg);
        assert_ne!(a, b);
    }

    #[test]
    fn schedule_digest_discriminates_structure() {
        use crate::collectives::{allreduce, broadcast, TargetHeuristic};
        let cl = switched(2, 4, 1);
        let pl = Placement::block(&cl);
        let a = broadcast::binomial(&pl, 0);
        assert_eq!(schedule_digest(&a), schedule_digest(&a.clone()));
        // Different root, different algorithm, different op all diverge.
        assert_ne!(schedule_digest(&a), schedule_digest(&broadcast::binomial(&pl, 1)));
        assert_ne!(
            schedule_digest(&a),
            schedule_digest(&broadcast::mc_aware(
                &cl,
                &pl,
                0,
                TargetHeuristic::FirstFit
            ))
        );
        assert_ne!(schedule_digest(&a), schedule_digest(&allreduce::ring(&pl)));
        // Payload sizing is part of the schedule's identity.
        assert_ne!(
            schedule_digest(&a),
            schedule_digest(&a.clone().with_total_bytes(1 << 20))
        );
        // A single dropped transfer changes the digest (the final
        // binomial round has several, so the schedule stays non-empty).
        let mut b = a.clone();
        b.rounds.last_mut().unwrap().xfers.pop();
        assert_ne!(schedule_digest(&a), schedule_digest(&b));
    }

    #[test]
    fn live_walks_mirror_the_constructed_key() {
        // live_digest / live_family_digest / matches must agree with the
        // allocating path (`Fingerprint::new` + digest/family_digest/==)
        // across relabeling (uniform grid), verbatim (irregular line,
        // straggler physics, robustness draws) and both placements.
        let mut strag = TuneCfg::default();
        strag.sim = strag.sim.with_slowdown(0, 2.0);
        let cfgs = vec![
            TuneCfg::default(),
            TuneCfg::default().with_msg_bytes(1 << 20),
            TuneCfg::default().with_robustness(2, 9, 8.0),
            strag,
        ];
        let clusters =
            vec![switched(3, 4, 2), switched(2, 2, 1), crate::topology::line(3, 2, 1)];
        let colls = [
            Collective::Allreduce,
            Collective::Broadcast { root: 1 },
            Collective::AllToAll,
        ];
        for cl in &clusters {
            for pl in [Placement::block(cl), Placement::round_robin(cl)] {
                for &coll in &colls {
                    for cfg in &cfgs {
                        let fp = Fingerprint::new(cl, &pl, coll, cfg);
                        assert_eq!(fp.digest(), live_digest(cl, &pl, coll, cfg));
                        assert_eq!(
                            fp.family_digest(),
                            live_family_digest(cl, &pl, coll, cfg)
                        );
                        assert!(fp.matches(cl, &pl, coll, cfg));
                    }
                }
            }
        }
        // And a matched negative for every ingredient class: op, size,
        // shape, interconnect kind.
        let cl = switched(3, 4, 2);
        let pl = Placement::block(&cl);
        let base = Fingerprint::new(&cl, &pl, Collective::Allreduce, &TuneCfg::default());
        assert!(!base.matches(&cl, &pl, Collective::AllToAll, &TuneCfg::default()));
        assert!(!base.matches(
            &cl,
            &pl,
            Collective::Allreduce,
            &TuneCfg::default().with_msg_bytes(1 << 20)
        ));
        let bigger = switched(4, 4, 2);
        assert!(!base.matches(
            &bigger,
            &Placement::block(&bigger),
            Collective::Allreduce,
            &TuneCfg::default()
        ));
        let line = crate::topology::line(3, 4, 2);
        assert!(!base.matches(
            &line,
            &Placement::block(&line),
            Collective::Allreduce,
            &TuneCfg::default()
        ));
    }

    #[test]
    fn family_digest_is_size_invariant_and_nothing_else() {
        let cl = switched(3, 4, 2);
        let pl = Placement::block(&cl);
        let at = |bytes: u64| {
            Fingerprint::new(
                &cl,
                &pl,
                Collective::Allreduce,
                &TuneCfg::default().with_msg_bytes(bytes),
            )
        };
        let small = at(1 << 10);
        let large = at(1 << 26);
        assert_ne!(small, large);
        assert_ne!(small.digest(), large.digest());
        assert_eq!(small.family_digest(), large.family_digest());
        assert_eq!(small.msg_bytes(), 1 << 10);
        // Any non-size ingredient splits the family.
        let other_coll = Fingerprint::new(
            &cl,
            &pl,
            Collective::Broadcast { root: 0 },
            &TuneCfg::default().with_msg_bytes(1 << 10),
        );
        assert_ne!(small.family_digest(), other_coll.family_digest());
        let cl2 = switched(4, 4, 2);
        let other_shape = Fingerprint::new(
            &cl2,
            &Placement::block(&cl2),
            Collective::Allreduce,
            &TuneCfg::default().with_msg_bytes(1 << 10),
        );
        assert_ne!(small.family_digest(), other_shape.family_digest());
    }

    #[test]
    fn record_xfers_does_not_discriminate() {
        let cl = switched(2, 2, 1);
        let pl = Placement::block(&cl);
        let plain = TuneCfg::default();
        let mut recording = TuneCfg::default();
        recording.sim.record_xfers = true;
        let a = Fingerprint::new(&cl, &pl, Collective::Allreduce, &plain);
        let b = Fingerprint::new(&cl, &pl, Collective::Allreduce, &recording);
        assert_eq!(a, b);
    }
}
