//! Schedule autotuning: pick the best collective algorithm for a
//! topology, automatically.
//!
//! The paper's central claim is that the *right* schedule depends on the
//! machine model: flat binomial trees win on single-core switches,
//! hierarchical leader schemes on modest SMP clusters, and the mc-aware
//! builders pull ahead as core counts and NIC degrees grow. Hand-picking
//! per experiment does not scale to a framework; this module makes the
//! choice a cached, first-class subsystem (following Barchet-Estefanel &
//! Mounié's *Fast Tuning of Intra-Cluster Collective Communications*: a
//! static decision stage refined by measurement, memoized per topology).
//!
//! Selection is **payload-size-aware**: every candidate (and the flat
//! baseline) is sized to [`TuneCfg::msg_bytes`] before pricing, the
//! registry sweeps pipeline segment counts
//! ([`fn@crate::collectives::segmented`] over the chain substrate), and the
//! size class is part of the cache fingerprint — so the decision is the
//! best (algorithm, segment count) for this topology *at this size*.
//!
//! Pipeline (see `rust/src/README.md` for the full diagram):
//!
//! ```text
//! (Cluster, Placement, Collective, TuneCfg{msg_bytes, …})
//!        │
//!        ▼
//!  registry::candidates_for        every applicable builder variant,
//!        │                         heuristic / slot / segment sweeps
//!        ▼
//!  stage 1: Multicore model cost   uniform M×C grid + block placement?
//!        │                         price through model::analytic closed
//!        │                         forms on the symmetry quotient (no
//!        │                         schedule built); otherwise build +
//!        │                         size + legalize + price in
//!        │                         byte-weighted rounds. Keep the
//!        │                         `shortlist` best either way.
//!        ▼
//!  stage 2: sim::simulate          continuous-time confirmation over the
//!        │                         shortlist ∪ {flat baseline}; above
//!        │                         TuneCfg::quotient_sim_cap ranks the
//!        │                         pool is confirmed on a representative
//!        │                         grid and the Decision carries no
//!        │                         schedule (materialize on demand)
//!        ▼
//!  Decision ──▶ DecisionCache      keyed by canonical Fingerprint
//!                                  (size class included, relabeling-
//!                                  invariant on uniform grids); sharded
//!                                  + RwLocked for concurrent serving —
//!                                  a repeat lookup is one read-locked
//!                                  hash probe, zero allocation, and a
//!                                  miss warm-starts from the nearest
//!                                  cached size class in its family
//! ```
//!
//! Contract: the selected schedule's simulated time never exceeds the
//! flat baseline's, because the baseline always participates in stage 2
//! ([`selector`] docs). With [`TuneCfg::robustness`] enabled
//! ([`Robustness::draws`] > 0), stage 2 additionally re-simulates the
//! pool under sampled single-machine straggler scenarios and picks the
//! best *mean degraded* makespan among the candidates that keep that
//! clean-run contract — so a robust decision is never worse than the
//! baseline on a healthy cluster and never degrades worse than the
//! clean pick under the sampled stragglers. Entry points:
//!
//! * [`select`] — one-shot tuning, no cache.
//! * [`select_many`] — batched tuning of several collectives on one
//!   topology: the lowered topology context is compiled once and both
//!   stages sweep all candidates together (in parallel on big
//!   topologies).
//! * [`DecisionCache`] — explicit cache for loops over many topologies.
//! * [`Tuned`] — thread-safe facade used by
//!   [`crate::coordinator::Communicator`]; this is what the trainer and
//!   the CLI go through.
//!
//! Both selection stages run over the flat lowered IR
//! ([`crate::sched::lowered`]): stage 1 prices candidates with
//! [`crate::model::Multicore::cost_detail_lowered`], stage 2 confirms
//! with [`crate::sim::simulate_lowered`] against reusable
//! [`crate::sim::SimArena`] scratch.

pub mod cache;
pub mod fingerprint;
pub mod registry;
pub mod selector;

pub use cache::{CacheConfig, CacheStats, DecisionCache};
pub use fingerprint::{live_digest, live_family_digest, Fingerprint};
pub use registry::{
    analytic_cost, candidates_for, flat_baseline, has_analytic, CandidateId,
    Collective, SegBase, SEGMENT_SWEEP,
};
pub use selector::{
    select, select_many, select_many_seeded, select_seeded, Decision, Robustness,
    TuneCfg,
};

use std::sync::Arc;

use crate::sched::Schedule;
use crate::topology::{Cluster, Placement};

/// Thread-safe autotuner: a [`TuneCfg`] plus a shared [`DecisionCache`].
/// Stateless with respect to topology, so one instance can serve any
/// number of clusters/placements — and, since the cache is sharded and
/// internally synchronized, any number of querying threads: concurrent
/// hits take one shard's read lock each (no exclusive lock, no global
/// serialization point), and decisions come back as [`Arc<Decision>`] so
/// no lock is held while a caller materializes or executes a schedule.
#[derive(Debug)]
pub struct Tuned {
    pub cfg: TuneCfg,
    cache: DecisionCache,
}

impl Default for Tuned {
    fn default() -> Self {
        Self::new(TuneCfg::default())
    }
}

impl Tuned {
    pub fn new(cfg: TuneCfg) -> Self {
        Self { cfg, cache: DecisionCache::new() }
    }

    /// Facade with explicit cache shape (shard count, capacity bound) —
    /// serving deployments and the traffic bench.
    pub fn with_cache(cfg: TuneCfg, cache: CacheConfig) -> Self {
        Self { cfg, cache: DecisionCache::with_config(cache) }
    }

    /// The tuned schedule for `collective` on this topology (cached).
    /// Above [`TuneCfg::quotient_sim_cap`] ranks the cached decision
    /// carries no schedule, so this materializes the winner on demand —
    /// callers that only need the *choice* at scale should use
    /// [`Tuned::decision`] instead.
    pub fn schedule(
        &self,
        cluster: &Cluster,
        placement: &Placement,
        collective: Collective,
    ) -> crate::Result<Schedule> {
        self.decision(cluster, placement, collective)?
            .materialize(cluster, placement, &self.cfg)
    }

    /// The full tuning decision, shared straight out of the cache.
    pub fn decision(
        &self,
        cluster: &Cluster,
        placement: &Placement,
        collective: Collective,
    ) -> crate::Result<Arc<Decision>> {
        self.cache.get_or_tune(cluster, placement, collective, &self.cfg)
    }

    /// [`Tuned::decision`] at an explicit payload size, overriding
    /// [`TuneCfg::msg_bytes`] for this query only. This is the
    /// tuning-as-a-service entry point for size-varied traffic: every
    /// size class keeps its own cache entry, and a miss warm-starts from
    /// the nearest cached neighbor in the same family.
    pub fn decision_sized(
        &self,
        cluster: &Cluster,
        placement: &Placement,
        collective: Collective,
        msg_bytes: u64,
    ) -> crate::Result<Arc<Decision>> {
        if msg_bytes == self.cfg.msg_bytes {
            return self.decision(cluster, placement, collective);
        }
        let cfg = self.cfg.clone().with_msg_bytes(msg_bytes);
        self.cache.get_or_tune(cluster, placement, collective, &cfg)
    }

    /// Drop the cached decision for one fingerprint (online re-planning
    /// invalidates decisions tuned for a topology that no longer exists).
    pub fn invalidate(&self, fp: &Fingerprint) -> bool {
        self.cache.invalidate(fp)
    }

    /// Drop every cached decision and reset every counter.
    pub fn clear(&self) {
        self.cache.clear()
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{switched, Placement};

    #[test]
    fn facade_caches_across_calls() {
        let tuner = Tuned::default();
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let a = tuner.schedule(&cl, &pl, Collective::Allreduce).unwrap();
        let b = tuner.schedule(&cl, &pl, Collective::Allreduce).unwrap();
        assert_eq!(a, b);
        let s = tuner.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn facade_sized_queries_and_clear() {
        let tuner = Tuned::default();
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let small =
            tuner.decision_sized(&cl, &pl, Collective::Allreduce, 4 << 10).unwrap();
        let large =
            tuner.decision_sized(&cl, &pl, Collective::Allreduce, 64 << 20).unwrap();
        assert_eq!(small.schedule().msg.total_bytes, 4 << 10);
        assert_eq!(large.schedule().msg.total_bytes, 64 << 20);
        let s = tuner.stats();
        assert_eq!((s.misses, s.entries), (2, 2));
        assert_eq!(s.warm_hits, 1, "second size class warm-starts off the first");

        // Clearing empties the cache but never invalidates handed-out
        // Arcs; the next query is a cold miss again.
        tuner.clear();
        let s = tuner.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.warm_hits), (0, 0, 0, 0));
        assert_eq!(small.schedule().msg.total_bytes, 4 << 10);
        tuner.decision_sized(&cl, &pl, Collective::Allreduce, 4 << 10).unwrap();
        assert_eq!(tuner.stats().misses, 1);
    }

    #[test]
    fn facade_serves_multiple_topologies() {
        let tuner = Tuned::default();
        for m in [2usize, 3, 4] {
            let cl = switched(m, 2, 1);
            let pl = Placement::block(&cl);
            tuner.schedule(&cl, &pl, Collective::Broadcast { root: 0 }).unwrap();
        }
        assert_eq!(tuner.stats().entries, 3);
    }
}
