//! Candidate registry: which schedule builders can serve a collective on
//! a given topology, including parameter sweeps (broadcast target
//! heuristics, NIC-slot counts).
//!
//! Applicability rules mirror the builders' own premises:
//!
//! * Flat algorithms (binomial trees, rings, pairwise/Bruck exchanges)
//!   assume any-to-any reachability — the LogP premise — so they are
//!   offered only on [`Interconnect::FullSwitch`] clusters.
//! * The machine-level exchange patterns behind the mc-aware allgather /
//!   all-to-all / allreduce builders also need any-to-any machine
//!   reachability; on explicit graphs only the dissemination-style ops
//!   (broadcast, gather, scatter, reduce) apply.
//! * `recursive_doubling` / `rabenseifner` require power-of-two ranks.
//! * Slot sweeps enumerate powers of two up to each topology's
//!   bottleneck `min(degree, cores)`.

use crate::collectives::{
    allgather, allreduce, alltoall, broadcast, gather, reduce, reduce_scatter, scatter,
    segmented::segmented, TargetHeuristic,
};
use crate::model::{analytic, McCost, Multicore, UniformGrid};
use crate::sched::Schedule;
use crate::topology::{Cluster, Interconnect, Placement};
use crate::Rank;

/// Segment counts the tuner sweeps for pipelined candidates. Powers of
/// two: the crossover moves roughly geometrically with payload size, so
/// a geometric sweep brackets it.
pub const SEGMENT_SWEEP: [u32; 3] = [2, 4, 8];

/// A collective request, parameterized the way a caller sees it (no
/// algorithm choice — that is the tuner's job).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    Broadcast { root: Rank },
    Gather { root: Rank },
    Scatter { root: Rank },
    Reduce { root: Rank },
    Allgather,
    AllToAll,
    Allreduce,
    ReduceScatter,
}

impl Collective {
    /// Short stable name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Collective::Broadcast { .. } => "broadcast",
            Collective::Gather { .. } => "gather",
            Collective::Scatter { .. } => "scatter",
            Collective::Reduce { .. } => "reduce",
            Collective::Allgather => "allgather",
            Collective::AllToAll => "alltoall",
            Collective::Allreduce => "allreduce",
            Collective::ReduceScatter => "reduce_scatter",
        }
    }
}

/// One fully-parameterized builder invocation. Identifies a candidate
/// uniquely, builds deterministically, and is cheap to store in cache
/// decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CandidateId {
    BcastFlatTree { root: Rank },
    BcastBinomial { root: Rank },
    BcastHierarchical { root: Rank },
    BcastMcAware { root: Rank, heuristic: TargetHeuristic },
    GatherFlat { root: Rank },
    GatherInverseBinomial { root: Rank },
    GatherMcAware { root: Rank },
    ScatterFlat { root: Rank },
    ScatterBinomial { root: Rank },
    ScatterMcAware { root: Rank },
    ReduceBinomial { root: Rank },
    ReduceMcAware { root: Rank },
    AllgatherRing,
    AllgatherMcAware { slots: usize },
    AlltoallPairwise,
    AlltoallBruck,
    AlltoallLeaderAggregated { slots: usize },
    AllreduceRing,
    AllreduceRecursiveDoubling,
    AllreduceRabenseifner,
    AllreduceHierarchicalMc,
    ReduceScatterRing,
    ReduceScatterRecursiveHalving,
    /// Machine-chain pipeline broadcast (unsegmented substrate).
    BcastChainMc { root: Rank },
    /// [`fn@crate::collectives::segmented`] applied to `base` with this
    /// wave count — the tuner picks algorithm *and* segment size.
    Segmented { base: SegBase, segments: u32 },
}

/// Inner builders the segmentation sweep applies to. A subset of the
/// registry: pipelining pays on schedules with idle-NIC structure (the
/// chain), and the ring variants keep the differential suites honest on
/// reduction/segment interaction (they never win stage 1 — segmenting an
/// always-busy ring only adds round constants — but they must stay
/// *correct*).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SegBase {
    BcastChainMc { root: Rank },
    AllreduceRing,
    ReduceScatterRing,
    AllgatherRing,
}

impl SegBase {
    /// The unsegmented candidate this base corresponds to.
    pub fn id(&self) -> CandidateId {
        match *self {
            SegBase::BcastChainMc { root } => CandidateId::BcastChainMc { root },
            SegBase::AllreduceRing => CandidateId::AllreduceRing,
            SegBase::ReduceScatterRing => CandidateId::ReduceScatterRing,
            SegBase::AllgatherRing => CandidateId::AllgatherRing,
        }
    }
}

impl CandidateId {
    /// Human-readable label, e.g. `bcast/mc-aware/coverage-aware`.
    pub fn label(&self) -> String {
        match self {
            CandidateId::BcastFlatTree { .. } => "bcast/flat-tree".into(),
            CandidateId::BcastBinomial { .. } => "bcast/binomial".into(),
            CandidateId::BcastHierarchical { .. } => "bcast/hierarchical".into(),
            CandidateId::BcastMcAware { heuristic, .. } => {
                format!("bcast/mc-aware/{}", heuristic.name())
            }
            CandidateId::GatherFlat { .. } => "gather/flat".into(),
            CandidateId::GatherInverseBinomial { .. } => "gather/inverse-binomial".into(),
            CandidateId::GatherMcAware { .. } => "gather/mc-aware".into(),
            CandidateId::ScatterFlat { .. } => "scatter/flat".into(),
            CandidateId::ScatterBinomial { .. } => "scatter/binomial".into(),
            CandidateId::ScatterMcAware { .. } => "scatter/mc-aware".into(),
            CandidateId::ReduceBinomial { .. } => "reduce/binomial".into(),
            CandidateId::ReduceMcAware { .. } => "reduce/mc-aware".into(),
            CandidateId::AllgatherRing => "allgather/ring".into(),
            CandidateId::AllgatherMcAware { slots } => {
                format!("allgather/mc-aware/slots={slots}")
            }
            CandidateId::AlltoallPairwise => "alltoall/pairwise".into(),
            CandidateId::AlltoallBruck => "alltoall/bruck".into(),
            CandidateId::AlltoallLeaderAggregated { slots } => {
                format!("alltoall/leader-aggregated/slots={slots}")
            }
            CandidateId::AllreduceRing => "allreduce/ring".into(),
            CandidateId::AllreduceRecursiveDoubling => "allreduce/recursive-doubling".into(),
            CandidateId::AllreduceRabenseifner => "allreduce/rabenseifner".into(),
            CandidateId::AllreduceHierarchicalMc => "allreduce/hierarchical-mc".into(),
            CandidateId::ReduceScatterRing => "reduce_scatter/ring".into(),
            CandidateId::ReduceScatterRecursiveHalving => {
                "reduce_scatter/recursive-halving".into()
            }
            CandidateId::BcastChainMc { .. } => "bcast/chain-mc".into(),
            CandidateId::Segmented { base, segments } => {
                format!("{}+seg{segments}", base.id().label())
            }
        }
    }

    /// Build the schedule this candidate denotes.
    pub fn build(&self, cluster: &Cluster, placement: &Placement) -> crate::Result<Schedule> {
        Ok(match *self {
            CandidateId::BcastFlatTree { root } => broadcast::flat_tree(placement, root),
            CandidateId::BcastBinomial { root } => broadcast::binomial(placement, root),
            CandidateId::BcastHierarchical { root } => {
                broadcast::hierarchical(cluster, placement, root)
            }
            CandidateId::BcastMcAware { root, heuristic } => {
                broadcast::mc_aware(cluster, placement, root, heuristic)
            }
            CandidateId::GatherFlat { root } => gather::flat_gather(placement, root),
            CandidateId::GatherInverseBinomial { root } => {
                gather::inverse_binomial(placement, root)
            }
            CandidateId::GatherMcAware { root } => gather::mc_aware(cluster, placement, root),
            CandidateId::ScatterFlat { root } => scatter::flat_scatter(placement, root),
            CandidateId::ScatterBinomial { root } => scatter::binomial(placement, root),
            CandidateId::ScatterMcAware { root } => {
                scatter::mc_aware(cluster, placement, root)
            }
            CandidateId::ReduceBinomial { root } => reduce::binomial(placement, root),
            CandidateId::ReduceMcAware { root } => reduce::mc_aware(cluster, placement, root),
            CandidateId::AllgatherRing => allgather::ring(placement),
            CandidateId::AllgatherMcAware { slots } => {
                allgather::mc_aware(cluster, placement, slots)
            }
            CandidateId::AlltoallPairwise => alltoall::pairwise(placement),
            CandidateId::AlltoallBruck => alltoall::bruck(placement),
            CandidateId::AlltoallLeaderAggregated { slots } => {
                alltoall::leader_aggregated(cluster, placement, slots)
            }
            CandidateId::AllreduceRing => allreduce::ring(placement),
            CandidateId::AllreduceRecursiveDoubling => {
                allreduce::recursive_doubling(placement)?
            }
            CandidateId::AllreduceRabenseifner => allreduce::rabenseifner(placement)?,
            CandidateId::AllreduceHierarchicalMc => {
                allreduce::hierarchical_mc(cluster, placement)
            }
            CandidateId::ReduceScatterRing => reduce_scatter::ring(placement),
            CandidateId::ReduceScatterRecursiveHalving => {
                reduce_scatter::recursive_halving(placement)?
            }
            CandidateId::BcastChainMc { root } => {
                broadcast::chain_mc(cluster, placement, root)
            }
            CandidateId::Segmented { base, segments } => {
                let inner = base.id().build(cluster, placement)?;
                segmented(cluster, placement, &inner, segments)?
            }
        })
    }
}

fn is_switch(cluster: &Cluster) -> bool {
    matches!(cluster.interconnect, Interconnect::FullSwitch)
}

/// The bottleneck NIC-slot count: `min` over machines of
/// `min(degree, hosted ranks)`, at least 1.
fn min_slots(cluster: &Cluster, placement: &Placement) -> usize {
    (0..cluster.num_machines())
        .map(|m| cluster.degree(m).min(placement.ranks_on(m).len()))
        .min()
        .unwrap_or(1)
        .max(1)
}

/// Slot sweep: powers of two up to `kmin`, plus `kmin` itself.
fn slot_sweep(kmin: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut s = 1usize;
    while s < kmin {
        out.push(s);
        s *= 2;
    }
    out.push(kmin);
    out
}

/// Every candidate applicable to `collective` on this topology. The
/// result is non-empty for dissemination ops on any connected cluster and
/// for all ops on switched clusters; exchange-style ops on explicit
/// graphs yield an empty set (no builder supports them yet).
pub fn candidates_for(
    collective: Collective,
    cluster: &Cluster,
    placement: &Placement,
) -> Vec<CandidateId> {
    let switch = is_switch(cluster);
    let n = placement.num_ranks();
    let mut out = Vec::new();
    match collective {
        Collective::Broadcast { root } => {
            if switch {
                out.push(CandidateId::BcastFlatTree { root });
                out.push(CandidateId::BcastBinomial { root });
                if cluster.num_machines() >= 2 {
                    // The pipeline substrate plus its segment sweep: the
                    // tuner picks the wave count per (topology, size).
                    out.push(CandidateId::BcastChainMc { root });
                    for segments in SEGMENT_SWEEP {
                        out.push(CandidateId::Segmented {
                            base: SegBase::BcastChainMc { root },
                            segments,
                        });
                    }
                }
            }
            out.push(CandidateId::BcastHierarchical { root });
            for heuristic in [
                TargetHeuristic::FirstFit,
                TargetHeuristic::FastestNodeFirst,
                TargetHeuristic::HighestDegreeFirst,
                TargetHeuristic::CoverageAware,
            ] {
                out.push(CandidateId::BcastMcAware { root, heuristic });
            }
        }
        Collective::Gather { root } => {
            if switch {
                out.push(CandidateId::GatherFlat { root });
                out.push(CandidateId::GatherInverseBinomial { root });
            }
            out.push(CandidateId::GatherMcAware { root });
        }
        Collective::Scatter { root } => {
            if switch {
                out.push(CandidateId::ScatterFlat { root });
                out.push(CandidateId::ScatterBinomial { root });
            }
            out.push(CandidateId::ScatterMcAware { root });
        }
        Collective::Reduce { root } => {
            if switch {
                out.push(CandidateId::ReduceBinomial { root });
            }
            out.push(CandidateId::ReduceMcAware { root });
        }
        Collective::Allgather => {
            if switch {
                out.push(CandidateId::AllgatherRing);
                if n > 1 {
                    out.push(CandidateId::Segmented {
                        base: SegBase::AllgatherRing,
                        segments: 2,
                    });
                }
                for slots in slot_sweep(min_slots(cluster, placement)) {
                    out.push(CandidateId::AllgatherMcAware { slots });
                }
            }
        }
        Collective::AllToAll => {
            if switch {
                out.push(CandidateId::AlltoallPairwise);
                out.push(CandidateId::AlltoallBruck);
                for slots in slot_sweep(min_slots(cluster, placement)) {
                    out.push(CandidateId::AlltoallLeaderAggregated { slots });
                }
            }
        }
        Collective::Allreduce => {
            if switch {
                out.push(CandidateId::AllreduceRing);
                if n > 1 {
                    out.push(CandidateId::Segmented {
                        base: SegBase::AllreduceRing,
                        segments: 2,
                    });
                }
                if n.is_power_of_two() {
                    out.push(CandidateId::AllreduceRecursiveDoubling);
                    out.push(CandidateId::AllreduceRabenseifner);
                }
                out.push(CandidateId::AllreduceHierarchicalMc);
            }
        }
        Collective::ReduceScatter => {
            if switch {
                out.push(CandidateId::ReduceScatterRing);
                if n > 1 {
                    out.push(CandidateId::Segmented {
                        base: SegBase::ReduceScatterRing,
                        segments: 2,
                    });
                }
                if n.is_power_of_two() {
                    out.push(CandidateId::ReduceScatterRecursiveHalving);
                }
            }
        }
    }
    out
}

/// Does this candidate have a closed-form [`McCost`] on uniform M×C grids
/// (see [`crate::model::analytic`])? The quotient fast path in the
/// selector engages only when *every* candidate of a collective answers
/// yes — a single `false` falls the whole collective back to
/// materialization, so adding a builder without a closed form degrades
/// gracefully instead of silently mispricing.
pub fn has_analytic(id: CandidateId) -> bool {
    matches!(
        id,
        CandidateId::BcastFlatTree { .. }
            | CandidateId::BcastBinomial { .. }
            | CandidateId::BcastHierarchical { .. }
            | CandidateId::BcastMcAware { .. }
            | CandidateId::BcastChainMc { .. }
            | CandidateId::AllreduceRing
            | CandidateId::AllreduceRecursiveDoubling
            | CandidateId::AllreduceRabenseifner
            | CandidateId::AllreduceHierarchicalMc
            | CandidateId::Segmented {
                base: SegBase::BcastChainMc { .. } | SegBase::AllreduceRing,
                ..
            }
    )
}

/// Closed-form [`Multicore`] cost of `id` on a uniform grid with a
/// block placement and a machine-leader root — bit-exact against
/// `cost_detail_lowered` on the materialized (legalized) schedule.
/// `None` when the candidate has no analytic form, or when its builder
/// premise fails (power-of-two ranks for the butterfly allreduces).
pub fn analytic_cost(
    id: CandidateId,
    model: &Multicore,
    grid: UniformGrid,
    msg_bytes: u64,
) -> Option<McCost> {
    Some(match id {
        CandidateId::BcastFlatTree { .. } => analytic::bcast_flat_tree(model, grid, msg_bytes),
        CandidateId::BcastBinomial { .. } => analytic::bcast_binomial(model, grid, msg_bytes),
        CandidateId::BcastHierarchical { .. } => {
            analytic::bcast_hierarchical(model, grid, msg_bytes)
        }
        CandidateId::BcastMcAware { .. } => analytic::bcast_mc_aware(model, grid, msg_bytes),
        CandidateId::BcastChainMc { .. } => analytic::bcast_chain(model, grid, msg_bytes),
        CandidateId::Segmented { base: SegBase::BcastChainMc { .. }, segments } => {
            analytic::bcast_chain_segmented(model, grid, msg_bytes, segments)
        }
        CandidateId::Segmented { base: SegBase::AllreduceRing, segments } => {
            analytic::allreduce_ring_segmented(model, grid, msg_bytes, segments)
        }
        CandidateId::AllreduceRing => analytic::allreduce_ring(model, grid, msg_bytes),
        CandidateId::AllreduceRecursiveDoubling => {
            analytic::allreduce_recursive_doubling(model, grid, msg_bytes)?
        }
        CandidateId::AllreduceRabenseifner => {
            analytic::allreduce_rabenseifner(model, grid, msg_bytes)?
        }
        CandidateId::AllreduceHierarchicalMc => {
            analytic::allreduce_hierarchical_mc(model, grid, msg_bytes)
        }
        _ => return None,
    })
}

/// The multi-core-oblivious baseline the paper (and our guarantee in
/// [`crate::tune::select`]) measures against, when one applies: the best
/// classic algorithm for the op, ignoring machine structure.
pub fn flat_baseline(collective: Collective, cluster: &Cluster) -> Option<CandidateId> {
    if !is_switch(cluster) {
        return None; // flat algorithms assume any-to-any reachability
    }
    Some(match collective {
        Collective::Broadcast { root } => CandidateId::BcastBinomial { root },
        Collective::Gather { root } => CandidateId::GatherInverseBinomial { root },
        Collective::Scatter { root } => CandidateId::ScatterBinomial { root },
        Collective::Reduce { root } => CandidateId::ReduceBinomial { root },
        Collective::Allgather => CandidateId::AllgatherRing,
        Collective::AllToAll => CandidateId::AlltoallPairwise,
        Collective::Allreduce => CandidateId::AllreduceRing,
        Collective::ReduceScatter => CandidateId::ReduceScatterRing,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{gnp, switched};

    #[test]
    fn switch_offers_flat_and_mc_candidates() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let bcast = candidates_for(Collective::Broadcast { root: 0 }, &cl, &pl);
        assert!(bcast.contains(&CandidateId::BcastBinomial { root: 0 }));
        assert!(bcast.iter().any(|c| matches!(c, CandidateId::BcastMcAware { .. })));
        // Pipelining: the chain substrate plus one candidate per swept
        // segment count.
        assert!(bcast.contains(&CandidateId::BcastChainMc { root: 0 }));
        for segments in SEGMENT_SWEEP {
            assert!(bcast.contains(&CandidateId::Segmented {
                base: SegBase::BcastChainMc { root: 0 },
                segments,
            }));
        }
        assert_eq!(bcast.len(), 7 + 1 + SEGMENT_SWEEP.len());

        let ar = candidates_for(Collective::Allreduce, &cl, &pl);
        assert_eq!(ar.len(), 5); // 16 ranks: pow2 variants + segmented ring
    }

    #[test]
    fn graph_offers_only_topology_aware_candidates() {
        let cl = gnp(5, 0.6, 2, 1, 3);
        let pl = Placement::block(&cl);
        let bcast = candidates_for(Collective::Broadcast { root: 0 }, &cl, &pl);
        assert_eq!(bcast.len(), 5); // hierarchical + 4 heuristics
        assert!(flat_baseline(Collective::Broadcast { root: 0 }, &cl).is_none());
        assert!(candidates_for(Collective::Allreduce, &cl, &pl).is_empty());
    }

    #[test]
    fn slot_sweep_covers_powers_of_two() {
        assert_eq!(slot_sweep(1), vec![1]);
        assert_eq!(slot_sweep(2), vec![1, 2]);
        assert_eq!(slot_sweep(3), vec![1, 2, 3]);
        assert_eq!(slot_sweep(8), vec![1, 2, 4, 8]);
    }

    #[test]
    fn candidates_build_and_are_distinct() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        for coll in [
            Collective::Broadcast { root: 1 },
            Collective::Gather { root: 0 },
            Collective::Scatter { root: 2 },
            Collective::Reduce { root: 0 },
            Collective::Allgather,
            Collective::AllToAll,
            Collective::Allreduce,
            Collective::ReduceScatter,
        ] {
            let ids = candidates_for(coll, &cl, &pl);
            assert!(!ids.is_empty(), "{}", coll.name());
            let mut labels: Vec<String> = ids.iter().map(|c| c.label()).collect();
            labels.sort();
            labels.dedup();
            assert_eq!(labels.len(), ids.len(), "duplicate candidate for {}", coll.name());
            for id in ids {
                let s = id.build(&cl, &pl).unwrap();
                assert_eq!(s.num_ranks, pl.num_ranks(), "{}", id.label());
            }
        }
    }

    #[test]
    fn non_pow2_drops_butterfly_allreduces() {
        let cl = switched(3, 2, 1); // 6 ranks
        let pl = Placement::block(&cl);
        let ids = candidates_for(Collective::Allreduce, &cl, &pl);
        assert!(!ids.contains(&CandidateId::AllreduceRecursiveDoubling));
        assert!(!ids.contains(&CandidateId::AllreduceRabenseifner));
        assert!(ids.contains(&CandidateId::AllreduceRing));
        let rs = candidates_for(Collective::ReduceScatter, &cl, &pl);
        assert_eq!(
            rs,
            vec![
                CandidateId::ReduceScatterRing,
                CandidateId::Segmented { base: SegBase::ReduceScatterRing, segments: 2 }
            ]
        );
    }

    #[test]
    fn reduce_scatter_registered_with_baseline() {
        let cl = switched(2, 4, 2); // 8 ranks: pow2, halving applies
        let pl = Placement::block(&cl);
        let ids = candidates_for(Collective::ReduceScatter, &cl, &pl);
        assert_eq!(
            ids,
            vec![
                CandidateId::ReduceScatterRing,
                CandidateId::Segmented { base: SegBase::ReduceScatterRing, segments: 2 },
                CandidateId::ReduceScatterRecursiveHalving
            ]
        );
        assert_eq!(
            flat_baseline(Collective::ReduceScatter, &cl),
            Some(CandidateId::ReduceScatterRing)
        );
    }
}
