//! Two-stage candidate selection: rank by round-model cost, break ties
//! (and confirm) with the continuous-time simulator.
//!
//! Stage 1 prices every applicable candidate under the configured
//! [`Multicore`] model — cheap, round-based, and already enough to
//! discard grossly oversubscribed schedules (flat candidates are
//! legalized first, exactly as a real NIC-constrained cluster would
//! serialize them). The best [`TuneCfg::shortlist`] candidates advance.
//!
//! Stage 2 runs the shortlist through the continuous-time simulator and
//! picks the smallest simulated completion time. The flat baseline
//! ([`crate::tune::flat_baseline`]) is *always* added to stage 2 when the
//! topology admits one, which yields the tuner's contract:
//!
//! > **`select` never returns a schedule whose simulated time exceeds the
//! > flat baseline's.**
//!
//! Ties are broken by model cost, then candidate label, so selection is
//! fully deterministic.
//!
//! ## Execution strategy
//!
//! Both stages run over the lowered IR ([`crate::sched::lowered`]): the
//! topology context is compiled **once** per selection, every candidate
//! is priced through [`Multicore::cost_detail_lowered`], and stage-2
//! confirmation runs [`crate::sim::simulate_lowered`] against reusable
//! [`SimArena`] scratch. When the topology is large enough for it to
//! pay, candidates are evaluated in parallel with
//! [`std::thread::scope`] — each worker owns one arena, results land in
//! per-candidate slots, and the final argmin is sequential, so the
//! decision is identical whatever the worker count. [`select_many`]
//! amortizes all of this across several collectives on one topology.
//!
//! ## The symmetry-quotient fast path
//!
//! On a [`crate::topology::SymmetryClass::Uniform`] M×C grid with a block
//! placement and a machine-leader root, stage 1 does not materialize
//! anything: every candidate is priced through the closed forms in
//! [`crate::model::analytic`], which are bit-exact against
//! `cost_detail_lowered`, so the analytic shortlist is *the same
//! shortlist* the materializing path would cut. Below
//! [`TuneCfg::quotient_sim_cap`] ranks, only the stage-2 pool (a handful
//! of schedules) is then built and merged into the shared simulation
//! sweep — decisions are bit-identical to the full path, just cheaper.
//! Above the cap no full-size [`Schedule`] is ever built: the pool is
//! confirmed on a *representative* grid (same C and NIC count, at most 4
//! machines — one machine orbit is all a uniform topology has), the
//! winner is the representative-simulation argmin with analytic-cost and
//! label tie-breaks, and [`Decision::schedule`] comes back `None`
//! (materialize on demand with [`Decision::materialize`]). This is what
//! makes `tune::select` on a 100 000-rank grid a milliseconds affair.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::model::{legalize, Duplex, Multicore, UniformGrid};
use crate::sched::{LoweredSchedule, Schedule, TopoCtx};
use crate::sim::{simulate_lowered, SimArena, SimParams};
use crate::topology::{switched, Cluster, Placement, SymmetryClass};
use crate::util::Rng;

use super::registry::{analytic_cost, candidates_for, flat_baseline, CandidateId, Collective};

/// Minimum `num_ranks × candidates` before stage 1 fans out to threads.
const STAGE1_PAR_MIN_WORK: usize = 1 << 12;
/// Minimum total pool transfers before stage 2 fans out to threads.
const STAGE2_PAR_MIN_XFERS: usize = 1 << 13;

/// Robustness knob for stage-2 scoring. With `draws > 0`, every pool
/// candidate is additionally simulated under `draws` sampled straggler
/// scenarios — each draw slows one uniformly drawn machine's CPU
/// overheads by `factor` — and the winner is the candidate with the
/// best *mean degraded* makespan among those that still meet the
/// clean-run baseline contract. `draws == 0` (the default) leaves
/// selection purely clean-makespan driven, bit-identical to a tuner
/// without the knob. Folded into [`crate::tune::Fingerprint`], so clean
/// and robust decisions never share a cache entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Robustness {
    /// How many straggler scenarios to sample (0 = off).
    pub draws: usize,
    /// Seed for the deterministic machine draws.
    pub seed: u64,
    /// CPU-overhead slowdown applied to the drawn machine.
    pub factor: f64,
}

impl Default for Robustness {
    fn default() -> Self {
        Self { draws: 0, seed: 0x57A6, factor: 8.0 }
    }
}

/// Tuner configuration: the cost model used for stage-1 ranking (its
/// duplex assumption, `alpha` and byte weights are part of the cache
/// fingerprint), the simulator physics used for stage-2 confirmation,
/// the payload size the decision is for, and the shortlist width.
#[derive(Debug, Clone)]
pub struct TuneCfg {
    pub model: Multicore,
    pub sim: SimParams,
    /// How many stage-1 winners advance to simulation. Larger values
    /// trade tuning time for decision quality; `usize::MAX` simulates
    /// every candidate (exhaustive mode, used by ablations).
    pub shortlist: usize,
    /// Total payload bytes the decision is tuned for: every candidate
    /// (and the flat baseline) is sized to this before pricing, so the
    /// winner — algorithm *and* segment count — is specific to the
    /// (topology, size) pair. Folded into the cache
    /// [`crate::tune::Fingerprint`], so a 1 KB and a 1 GB request never
    /// share a cached decision.
    pub msg_bytes: u64,
    /// Digest of the [`crate::calibrate::MachineProfile`] this
    /// configuration was derived from (0 = hand-set constants). Part of
    /// the decision-cache [`crate::tune::Fingerprint`], so decisions
    /// tuned against one machine's measured physics are never served
    /// after a recalibration changes them.
    pub profile_digest: u64,
    /// Straggler-aware stage-2 scoring (off by default).
    pub robustness: Robustness,
    /// Enable the symmetry-quotient fast path (on by default): on
    /// uniform M×C grids stage 1 prices candidates analytically
    /// ([`crate::model::analytic`]) instead of materializing them.
    /// Bit-exact below [`TuneCfg::quotient_sim_cap`] ranks; purely a
    /// speed knob there, a feasibility knob above. Folded into the cache
    /// [`crate::tune::Fingerprint`].
    pub quotient: bool,
    /// Rank-count ceiling for materializing quotient-path schedules.
    /// At or below it the stage-2 pool is built and simulated on the
    /// real topology (decisions identical to the full path); above it
    /// the pool is confirmed on a representative grid and
    /// [`Decision::schedule`] is `None`. Folded into the cache
    /// [`crate::tune::Fingerprint`].
    pub quotient_sim_cap: usize,
}

impl Default for TuneCfg {
    fn default() -> Self {
        Self {
            model: Multicore::default(),
            sim: SimParams::lan_cluster(),
            shortlist: 4,
            msg_bytes: 16 << 10,
            profile_digest: 0,
            robustness: Robustness::default(),
            quotient: true,
            quotient_sim_cap: 4096,
        }
    }
}

impl TuneCfg {
    /// Tuner configuration derived from a measured machine profile:
    /// stage-1 ranking under [`Multicore::from_profile`] (byte weights
    /// included), stage-2 confirmation under [`SimParams::from_profile`],
    /// decisions sized for `msg_bytes`, and the profile's digest folded
    /// into every cache fingerprint.
    pub fn from_profile(p: &crate::calibrate::MachineProfile, msg_bytes: u64) -> Self {
        Self {
            model: Multicore::from_profile(p),
            sim: SimParams::from_profile(p),
            shortlist: 4,
            msg_bytes,
            profile_digest: p.digest(),
            robustness: Robustness::default(),
            quotient: true,
            quotient_sim_cap: 4096,
        }
    }

    /// Builder-style payload size override.
    pub fn with_msg_bytes(mut self, msg_bytes: u64) -> Self {
        self.msg_bytes = msg_bytes;
        self
    }

    /// Builder-style quotient-path toggle (primarily for differential
    /// testing: `with_quotient(false)` forces full materialization).
    pub fn with_quotient(mut self, enabled: bool) -> Self {
        self.quotient = enabled;
        self
    }

    /// Builder-style robustness override: score stage-2 candidates under
    /// `draws` sampled straggler scenarios (deterministically seeded by
    /// `seed`, each slowing one machine's CPU overheads by `factor`).
    pub fn with_robustness(mut self, draws: usize, seed: u64, factor: f64) -> Self {
        self.robustness = Robustness { draws, seed, factor };
        self
    }
}

/// The outcome of one tuning run: the winning schedule plus enough
/// context to audit the choice.
#[derive(Debug, Clone)]
pub struct Decision {
    pub choice: CandidateId,
    /// The winning schedule, legalized for `cfg.model` if the raw builder
    /// output was not already legal. `None` only for quotient-path
    /// decisions above [`TuneCfg::quotient_sim_cap`] ranks, where
    /// materializing the winner is exactly the cost the quotient avoids —
    /// use [`Decision::materialize`] (or [`Decision::schedule`]) there.
    pub schedule: Option<Schedule>,
    /// Stage-1 scalar cost of the winner (`ext + alpha * int`).
    pub model_cost: f64,
    /// Stage-2 simulated completion time of the winner, seconds. For an
    /// above-cap quotient decision this is measured on the representative
    /// grid, not the full topology.
    pub sim_time: f64,
    /// Simulated time of the flat baseline, when the topology admits one
    /// (representative-grid time for above-cap quotient decisions).
    pub baseline_sim: Option<f64>,
    /// Mean degraded makespan of the winner over the sampled straggler
    /// draws; `None` when robustness scoring is off
    /// ([`Robustness::draws`] == 0) — and for above-cap quotient
    /// decisions, where straggler scoring would need full-size
    /// simulation.
    pub robust_sim: Option<f64>,
    /// Candidates priced in stage 1 / simulated in stage 2.
    pub considered: usize,
    pub simulated: usize,
}

impl Decision {
    /// The winning schedule, for decisions that carry one. Panics on an
    /// above-cap quotient decision — call [`Decision::materialize`] when
    /// the topology may exceed [`TuneCfg::quotient_sim_cap`].
    pub fn schedule(&self) -> &Schedule {
        self.schedule
            .as_ref()
            .expect("above-cap quotient decision: use Decision::materialize")
    }

    /// The winning schedule, building it on demand when the quotient path
    /// skipped materialization: the choice's builder runs on the real
    /// topology, is sized to `cfg.msg_bytes`, and is legalized exactly as
    /// stage 1 would have legalized it. Note that for an above-cap
    /// decision this walks all P ranks — it is the caller opting into the
    /// cost the tuner avoided.
    pub fn materialize(
        &self,
        cluster: &Cluster,
        placement: &Placement,
        cfg: &TuneCfg,
    ) -> crate::Result<Schedule> {
        if let Some(s) = &self.schedule {
            return Ok(s.clone());
        }
        let mut built = self.choice.build(cluster, placement)?;
        built.set_total_bytes(cfg.msg_bytes);
        if cfg.model.cost_detail(cluster, placement, &built).is_ok() {
            return Ok(built);
        }
        Ok(legalize(&cfg.model, cluster, placement, &built))
    }

    /// Fractional improvement over the flat baseline (0.37 = 37% faster),
    /// when a baseline exists.
    pub fn win_margin(&self) -> Option<f64> {
        self.baseline_sim
            .map(|b| if b > 0.0 { 1.0 - self.sim_time / b } else { 0.0 })
    }

    /// The chosen pipeline segment count (1 = unsegmented winner).
    pub fn segments(&self) -> u32 {
        match self.choice {
            CandidateId::Segmented { segments, .. } => segments,
            _ => 1,
        }
    }
}

/// How many workers to use for `jobs` units whose total size is
/// `work_estimate`: 1 (run inline) below `min_work`, else up to one per
/// core, capped at the job count. The estimate is derived from the
/// topology alone, so the choice — and therefore thread spawning — is
/// deterministic per input.
fn worker_count(jobs: usize, work_estimate: usize, min_work: usize) -> usize {
    if jobs < 2 || work_estimate < min_work {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, jobs)
}

/// Run `f(scratch, i)` for every `i in 0..n_jobs` and collect results in
/// job order, with per-worker scratch built by `init` (`()` for stage 1,
/// a [`SimArena`] for stage 2). With `workers == 1` everything runs
/// inline on one scratch value; otherwise a [`std::thread::scope`] fans
/// jobs out over an atomic cursor, each worker owning its scratch.
/// Results are written to per-job slots, so the output is independent of
/// scheduling.
fn run_jobs<S, T, I, F>(n_jobs: usize, workers: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if workers <= 1 {
        let mut scratch = init();
        return (0..n_jobs).map(|i| f(&mut scratch, i)).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n_jobs).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut scratch = init();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n_jobs {
                        break;
                    }
                    let out = f(&mut scratch, i);
                    *slots[i].lock().expect("job slot poisoned") = Some(out);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("job slot poisoned")
                .expect("every job slot is filled")
        })
        .collect()
}

/// One priced candidate: id, its (possibly legalized) schedule, stage-1
/// scalar cost, and the compiled IR — kept so stage 2 simulates without
/// re-lowering.
type Priced<'t> = (CandidateId, Schedule, f64, LoweredSchedule<'t>);

/// Build one candidate, size it to the configured payload, and price it
/// under `model` over the lowered IR, legalizing first when the raw
/// builder output is not legal (exactly as a real NIC-constrained
/// cluster would serialize it).
fn build_and_price<'t>(
    ctx: &'t TopoCtx,
    model: &Multicore,
    cluster: &Cluster,
    placement: &Placement,
    msg_bytes: u64,
    id: CandidateId,
) -> crate::Result<Priced<'t>> {
    let mut built = id.build(cluster, placement)?;
    built.set_total_bytes(msg_bytes);
    if let Ok(low) = LoweredSchedule::compile(ctx, &built) {
        if let Ok(detail) = model.cost_detail_lowered(&low) {
            return Ok((id, built, detail.total(model.alpha), low));
        }
    }
    let schedule = legalize(model, cluster, placement, &built);
    let low = LoweredSchedule::compile(ctx, &schedule)?;
    let cost = model.cost_lowered(&low)?;
    Ok((id, schedule, cost, low))
}

/// Per-collective execution plan, chosen up front by the quotient
/// eligibility check.
enum Plan {
    /// Classic path: materialize and price every candidate.
    Full,
    /// Quotient path at or below [`TuneCfg::quotient_sim_cap`]: the
    /// analytic ranking already cut the stage-2 pool, so stage 1 builds
    /// only the pool members (the jobs are enqueued in final pool order)
    /// and stage 2 is shared with the other collectives as usual.
    Pool,
    /// Quotient path above the cap: no full-size schedule is ever built;
    /// the analytically costed pool is confirmed on a representative grid.
    Representative { grid: UniformGrid, pool: Vec<(CandidateId, f64)> },
}

/// Does this (topology, placement, collective) admit the analytic
/// quotient? Requires the fast path to be enabled, the full-duplex
/// round semantics the closed forms are derived for, a
/// [`SymmetryClass::Uniform`] machines×cores grid, a block placement
/// (rank `r` on machine `r / cores` — the layout the builders and the
/// closed forms both assume), and a collective with analytic coverage:
/// broadcast from a machine leader (any leader root reduces to root 0
/// under the grid's symmetry) or allreduce.
fn quotient_grid(
    cluster: &Cluster,
    placement: &Placement,
    collective: Collective,
    cfg: &TuneCfg,
) -> Option<UniformGrid> {
    if !cfg.quotient || cfg.model.duplex != Duplex::Full {
        return None;
    }
    let SymmetryClass::Uniform { machines, cores, nics } = cluster.symmetry else {
        return None;
    };
    if placement.num_ranks() != machines * cores {
        return None;
    }
    if (0..placement.num_ranks()).any(|r| placement.machine_of(r) != r / cores) {
        return None;
    }
    match collective {
        Collective::Broadcast { root } if root % cores == 0 => {}
        Collective::Allreduce => {}
        _ => return None,
    }
    Some(UniformGrid::new(machines, cores, nics))
}

/// Analytically price and rank every candidate on the quotient grid,
/// mirroring the full path's pool construction step for step: sort by
/// (cost, label), cut the shortlist, re-attach the flat baseline from
/// the tail. Because the closed forms are bit-exact against
/// [`Multicore::cost_detail_lowered`], the returned pool has the same
/// members in the same order as the materializing path would produce.
/// `None` if any candidate lacks a closed form — the whole collective
/// then falls back to full materialization.
fn quotient_rank(
    grid: UniformGrid,
    ids: &[CandidateId],
    baseline: Option<CandidateId>,
    cfg: &TuneCfg,
) -> Option<Vec<(CandidateId, f64)>> {
    let mut ranked: Vec<(CandidateId, f64)> = Vec::with_capacity(ids.len());
    for &id in ids {
        let cost = analytic_cost(id, &cfg.model, grid, cfg.msg_bytes)?;
        ranked.push((id, cost.total(cfg.model.alpha)));
    }
    ranked.sort_by(|a, b| {
        a.1.partial_cmp(&b.1)
            .expect("model costs are finite")
            .then_with(|| a.0.label().cmp(&b.0.label()))
    });
    let cut = cfg.shortlist.clamp(1, ranked.len());
    let mut pool: Vec<(CandidateId, f64)> = Vec::with_capacity(cut + 1);
    let mut rest: Vec<(CandidateId, f64)> = Vec::new();
    for (i, entry) in ranked.into_iter().enumerate() {
        if i < cut {
            pool.push(entry);
        } else {
            rest.push(entry);
        }
    }
    if let Some(b) = baseline {
        if !pool.iter().any(|(id, _)| *id == b) {
            if let Some(p) = rest.iter().position(|(id, _)| *id == b) {
                pool.push(rest.swap_remove(p));
            }
        }
    }
    Some(pool)
}

/// Confirm an above-cap quotient pool on a *representative* grid: same
/// cores and NIC count, at most 4 machines (a uniform topology has a
/// single machine orbit, so relative candidate behavior is preserved),
/// block placement. Runs sequentially over one arena — the pool is a
/// handful of schedules on a tiny grid. The winner is the argmin of
/// representative simulated time with analytic-cost and label
/// tie-breaks; the decision carries no schedule
/// ([`Decision::materialize`] builds it on demand).
fn decide_representative(
    grid: UniformGrid,
    pool: &[(CandidateId, f64)],
    baseline: Option<CandidateId>,
    considered: usize,
    cfg: &TuneCfg,
) -> crate::Result<Decision> {
    let rep = switched(grid.machines.min(4), grid.cores, grid.nics);
    let rep_pl = Placement::block(&rep);
    let ctx = TopoCtx::new(&rep, &rep_pl);
    let mut arena = SimArena::new();
    let mut sims = Vec::with_capacity(pool.len());
    for &(id, _) in pool {
        let (_, _, _, low) =
            build_and_price(&ctx, &cfg.model, &rep, &rep_pl, cfg.msg_bytes, id)?;
        sims.push(simulate_lowered(&low, &cfg.sim, &mut arena).t_end);
    }
    let mut baseline_sim = None;
    for (pi, (id, _)) in pool.iter().enumerate() {
        if baseline == Some(*id) {
            baseline_sim = Some(sims[pi]);
        }
    }
    let mut best = 0usize;
    for i in 1..pool.len() {
        let a = (sims[i], pool[i].1, pool[i].0.label());
        let b = (sims[best], pool[best].1, pool[best].0.label());
        if a < b {
            best = i;
        }
    }
    Ok(Decision {
        choice: pool[best].0,
        schedule: None,
        model_cost: pool[best].1,
        sim_time: sims[best],
        baseline_sim,
        robust_sim: None,
        considered,
        simulated: pool.len(),
    })
}

/// Select the best schedule for `collective` on this topology. See the
/// module docs for the two-stage procedure and the baseline guarantee.
pub fn select(
    cluster: &Cluster,
    placement: &Placement,
    collective: Collective,
    cfg: &TuneCfg,
) -> crate::Result<Decision> {
    let mut decisions = select_many(cluster, placement, &[collective], cfg)?;
    Ok(decisions.pop().expect("one collective in, one decision out"))
}

/// [`select`] with a warm-start hint: `warm` (typically the winning
/// candidate of a neighboring size class, supplied by the decision
/// cache's warm index) is ranked first through stage 1 and moved to the
/// front of the stage-2 pool. The hint changes *ordering only* — pool
/// membership, every simulated time, and the audited counters are
/// untouched, and the winner is the argmin under a strict total order
/// (sim time, model cost, candidate label — labels are unique within a
/// collective), which is invariant under pool permutation. So:
///
/// > **A warm-started decision is bit-identical, field by field, to the
/// > cold decision** (`warm_start_matches_cold` in `tests/prop_tune.rs`
/// > enforces this differentially).
///
/// A hint naming a candidate that is not applicable on this topology is
/// silently ignored — selection falls back to the plain registry sweep.
pub fn select_seeded(
    cluster: &Cluster,
    placement: &Placement,
    collective: Collective,
    cfg: &TuneCfg,
    warm: Option<CandidateId>,
) -> crate::Result<Decision> {
    let mut decisions =
        select_many_seeded(cluster, placement, &[collective], &[warm], cfg)?;
    Ok(decisions.pop().expect("one collective in, one decision out"))
}

/// Move the hinted candidate (when present) to the front of a slice of
/// keyed entries. Result-invariant by the strict-total-order argmin (see
/// [`select_seeded`]); applied to stage-1 job lists and stage-2 pools.
fn seed_front<T>(entries: &mut [T], hint: Option<CandidateId>, id_of: impl Fn(&T) -> CandidateId) {
    if let Some(h) = hint {
        if let Some(p) = entries.iter().position(|e| id_of(e) == h) {
            entries.swap(0, p);
        }
    }
}

/// Batched selection: tune several collectives on one topology in a
/// single pass. The topology context is compiled once, all candidates
/// across all collectives are priced in one (possibly parallel) stage-1
/// sweep, and the union of the stage-2 pools is confirmed in one
/// (possibly parallel) simulation sweep over shared arena scratch.
/// Decisions come back in input order and are identical to what
/// [`select`] returns for each collective alone.
pub fn select_many(
    cluster: &Cluster,
    placement: &Placement,
    collectives: &[Collective],
    cfg: &TuneCfg,
) -> crate::Result<Vec<Decision>> {
    select_many_seeded(cluster, placement, collectives, &[], cfg)
}

/// [`select_many`] with per-collective warm-start hints (see
/// [`select_seeded`] for the ordering-only contract). `hints` is either
/// empty (no hints) or one `Option<CandidateId>` per collective.
pub fn select_many_seeded(
    cluster: &Cluster,
    placement: &Placement,
    collectives: &[Collective],
    hints: &[Option<CandidateId>],
    cfg: &TuneCfg,
) -> crate::Result<Vec<Decision>> {
    assert!(
        hints.is_empty() || hints.len() == collectives.len(),
        "one warm hint per collective (or none at all)"
    );
    let hint = |ci: usize| hints.get(ci).copied().flatten();
    let ctx = TopoCtx::new(cluster, placement);

    // Plan each collective, then enumerate every (collective, candidate)
    // stage-1 job up front. A quotient-eligible collective prices its
    // candidates through the closed forms right here — only its stage-2
    // pool (or, above the cap, nothing at all) becomes stage-1 jobs.
    let mut jobs: Vec<CandidateId> = Vec::new();
    let mut job_ranges: Vec<std::ops::Range<usize>> = Vec::with_capacity(collectives.len());
    let mut plans: Vec<Plan> = Vec::with_capacity(collectives.len());
    let mut considered: Vec<usize> = Vec::with_capacity(collectives.len());
    let mut baselines: Vec<Option<CandidateId>> = Vec::with_capacity(collectives.len());
    for (ci, &coll) in collectives.iter().enumerate() {
        let mut ids = candidates_for(coll, cluster, placement);
        seed_front(&mut ids, hint(ci), |&id| id);
        if ids.is_empty() {
            anyhow::bail!(
                "no applicable schedule builder for {} on this topology \
                 (exchange-style collectives need a switched interconnect)",
                coll.name()
            );
        }
        considered.push(ids.len());
        let baseline = flat_baseline(coll, cluster);
        baselines.push(baseline);

        let start = jobs.len();
        let plan = match quotient_grid(cluster, placement, coll, cfg)
            .and_then(|grid| quotient_rank(grid, &ids, baseline, cfg).map(|p| (grid, p)))
        {
            Some((grid, mut pool)) if grid.num_ranks() <= cfg.quotient_sim_cap => {
                seed_front(&mut pool, hint(ci), |e| e.0);
                jobs.extend(pool.iter().map(|(id, _)| *id));
                Plan::Pool
            }
            // The representative must itself be materializable; when it
            // is not (single-machine topologies with enormous core
            // counts), the full path is the honest answer.
            Some((grid, pool))
                if grid.machines.min(4) * grid.cores <= cfg.quotient_sim_cap =>
            {
                Plan::Representative { grid, pool }
            }
            _ => {
                jobs.extend(ids);
                Plan::Full
            }
        };
        job_ranges.push(start..jobs.len());
        plans.push(plan);
    }

    // Stage 1: build, legalize if needed, price under the round model —
    // all candidates of all collectives in one sweep.
    let workers1 = worker_count(
        jobs.len(),
        ctx.num_ranks.saturating_mul(jobs.len()),
        STAGE1_PAR_MIN_WORK,
    );
    let priced = run_jobs(
        jobs.len(),
        workers1,
        || (),
        |_scratch, i| {
            build_and_price(&ctx, &cfg.model, cluster, placement, cfg.msg_bytes, jobs[i])
        },
    );
    let mut ranked_all: Vec<Priced<'_>> = Vec::with_capacity(jobs.len());
    for result in priced {
        ranked_all.push(result?);
    }

    // Per collective: rank, cut the shortlist, re-attach the baseline.
    // Job ranges are consecutive, so draining from the front walks them
    // in input order without cloning any schedule. Quotient Pool plans
    // enqueued their jobs already in final pool order, so their stage-1
    // results *are* the pool; Representative plans built nothing here.
    let mut remaining = ranked_all.into_iter();
    let mut pools: Vec<Vec<Priced<'_>>> = Vec::with_capacity(collectives.len());
    for (ci, _) in collectives.iter().enumerate() {
        let mut ranked: Vec<Priced<'_>> =
            remaining.by_ref().take(job_ranges[ci].len()).collect();
        if !matches!(plans[ci], Plan::Full) {
            pools.push(ranked);
            continue;
        }
        ranked.sort_by(|a, b| {
            a.2.partial_cmp(&b.2)
                .expect("model costs are finite")
                .then_with(|| a.0.label().cmp(&b.0.label()))
        });

        // Stage 2 pool: shortlist plus (always) the flat baseline.
        let cut = cfg.shortlist.clamp(1, ranked.len());
        let mut pool: Vec<Priced<'_>> = Vec::with_capacity(cut + 1);
        let mut rest: Vec<Priced<'_>> = Vec::new();
        for (i, entry) in ranked.into_iter().enumerate() {
            if i < cut {
                pool.push(entry);
            } else {
                rest.push(entry);
            }
        }
        if let Some(b) = baselines[ci] {
            if !pool.iter().any(|(id, _, _, _)| *id == b) {
                if let Some(p) = rest.iter().position(|(id, _, _, _)| *id == b) {
                    pool.push(rest.swap_remove(p));
                }
            }
        }
        // Warm hint: front-of-pool, membership untouched (result-invariant
        // — see `select_seeded`).
        seed_front(&mut pool, hint(ci), |e| e.0);
        pools.push(pool);
    }

    // Stage 2: simulate the union of the pools — the IR compiled in
    // stage 1 is reused, so confirmation is pure engine time over
    // per-worker arena scratch.
    let sim_jobs: Vec<(usize, usize)> = pools
        .iter()
        .enumerate()
        .flat_map(|(ci, pool)| (0..pool.len()).map(move |pi| (ci, pi)))
        .collect();
    let pool_xfers: usize = pools
        .iter()
        .flat_map(|pool| pool.iter())
        .map(|(_, _, _, low)| low.num_xfers())
        .sum();
    let workers2 = worker_count(sim_jobs.len(), pool_xfers, STAGE2_PAR_MIN_XFERS);
    let sim_results = run_jobs(sim_jobs.len(), workers2, SimArena::new, |arena, i| {
        let (ci, pi) = sim_jobs[i];
        simulate_lowered(&pools[ci][pi].3, &cfg.sim, arena).t_end
    });
    let mut sims: Vec<Vec<f64>> = pools.iter().map(|pool| vec![0.0; pool.len()]).collect();
    for (job, t_end) in sim_jobs.iter().zip(sim_results) {
        sims[job.0][job.1] = t_end;
    }

    // Stage 2b (robustness scoring): re-simulate every pool candidate
    // under `draws` sampled single-machine straggler scenarios and
    // average the degraded makespans. The draws are shared across all
    // candidates (and all collectives in the batch), so robust scores
    // are directly comparable. draws == 0 skips this entirely — clean
    // tuning stays bit-identical to a tuner without the knob.
    let draws = cfg.robustness.draws;
    let robust_means: Vec<Vec<f64>> = if draws > 0 {
        let mut rng = Rng::seed_from_u64(cfg.robustness.seed);
        let degraded: Vec<SimParams> = (0..draws)
            .map(|_| {
                let m = rng.gen_range(0..cluster.num_machines());
                cfg.sim.clone().with_slowdown(m, cfg.robustness.factor)
            })
            .collect();
        let n = sim_jobs.len() * draws;
        let workers3 =
            worker_count(n, pool_xfers.saturating_mul(draws), STAGE2_PAR_MIN_XFERS);
        let results = run_jobs(n, workers3, SimArena::new, |arena, i| {
            let (ci, pi) = sim_jobs[i / draws];
            simulate_lowered(&pools[ci][pi].3, &degraded[i % draws], arena).t_end
        });
        let mut means: Vec<Vec<f64>> =
            pools.iter().map(|pool| vec![0.0; pool.len()]).collect();
        for (i, t_end) in results.into_iter().enumerate() {
            let (ci, pi) = sim_jobs[i / draws];
            means[ci][pi] += t_end / draws as f64;
        }
        means
    } else {
        Vec::new()
    };

    // Pick each collective's winner (ties: model cost, then label —
    // deterministic).
    let mut decisions = Vec::with_capacity(collectives.len());
    for (ci, mut pool) in pools.into_iter().enumerate() {
        if let Plan::Representative { grid, pool: apool } = &plans[ci] {
            let mut apool = apool.clone();
            seed_front(&mut apool, hint(ci), |e| e.0);
            decisions.push(decide_representative(
                *grid,
                &apool,
                baselines[ci],
                considered[ci],
                cfg,
            )?);
            continue;
        }
        let sims = &sims[ci];
        let mut baseline_sim = None;
        for (pi, (id, _, _, _)) in pool.iter().enumerate() {
            if baselines[ci] == Some(*id) {
                baseline_sim = Some(sims[pi]);
            }
        }
        let mut best = 0usize;
        for i in 1..pool.len() {
            let a = (sims[i], pool[i].2, pool[i].0.label());
            let b = (sims[best], pool[best].2, pool[best].0.label());
            if a < b {
                best = i;
            }
        }
        let mut robust_sim = None;
        if draws > 0 {
            // Robust selection: among candidates that still honor the
            // clean-run baseline contract (the clean winner always
            // qualifies, so the scan never empties), argmin the mean
            // degraded makespan; ties fall back to the clean ordering.
            let robust = &robust_means[ci];
            for i in 0..pool.len() {
                if let Some(b) = baseline_sim {
                    if sims[i] > b + 1e-12 {
                        continue;
                    }
                }
                let a = (robust[i], sims[i], pool[i].2, pool[i].0.label());
                let b = (robust[best], sims[best], pool[best].2, pool[best].0.label());
                if a < b {
                    best = i;
                }
            }
            robust_sim = Some(robust[best]);
        }
        let simulated = pool.len();
        let (choice, schedule, model_cost, _low) = pool.swap_remove(best);
        decisions.push(Decision {
            choice,
            schedule: Some(schedule),
            model_cost,
            sim_time: sims[best],
            baseline_sim,
            robust_sim,
            considered: considered[ci],
            simulated,
        });
    }
    Ok(decisions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::symexec;
    use crate::topology::{switched, Placement};
    use crate::tune::Collective;

    #[test]
    fn broadcast_on_fat_cluster_prefers_mc_aware() {
        // 16 machines x 8 cores x 4 NICs: the paper's regime where
        // (k+1)^t dissemination crushes the binomial tree.
        let cl = switched(16, 8, 4);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let d = select(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        symexec::verify(d.schedule()).unwrap();
        assert!(
            matches!(d.choice, CandidateId::BcastMcAware { .. }),
            "expected mc-aware, got {}",
            d.choice.label()
        );
        let base = d.baseline_sim.expect("switch has a flat baseline");
        assert!(d.sim_time <= base, "tuned {} > baseline {base}", d.sim_time);
        assert!(d.win_margin().unwrap() > 0.0);
    }

    #[test]
    fn single_machine_broadcast_is_one_write() {
        let cl = switched(1, 8, 1);
        let pl = Placement::block(&cl);
        let d = select(&cl, &pl, Collective::Broadcast { root: 0 }, &TuneCfg::default())
            .unwrap();
        assert_eq!(d.schedule().external_messages(), 0);
        assert!(d.sim_time <= d.baseline_sim.unwrap());
    }

    #[test]
    fn allreduce_selects_and_beats_baseline() {
        let cl = switched(4, 8, 4);
        let pl = Placement::block(&cl);
        let d = select(&cl, &pl, Collective::Allreduce, &TuneCfg::default()).unwrap();
        symexec::verify(d.schedule()).unwrap();
        assert!(d.sim_time <= d.baseline_sim.unwrap());
        assert!(d.considered >= 4);
        assert!(d.simulated <= d.considered);
    }

    #[test]
    fn selection_is_size_aware_with_segment_sweep() {
        // The whole point of the sized pipeline: on the same topology the
        // winner changes with payload size, and for a bandwidth-dominated
        // payload the pick is a *segmented* pipeline that beats the flat
        // baseline in simulated time.
        let cl = switched(8, 4, 2);
        let pl = Placement::block(&cl);
        let coll = Collective::Broadcast { root: 0 };
        let small = select(&cl, &pl, coll, &TuneCfg::default().with_msg_bytes(512))
            .unwrap();
        let large = select(&cl, &pl, coll, &TuneCfg::default().with_msg_bytes(64 << 20))
            .unwrap();
        assert_ne!(
            small.choice, large.choice,
            "512 B and 64 MiB must tune differently: both chose {}",
            small.choice.label()
        );
        assert!(
            matches!(large.choice, CandidateId::Segmented { .. }),
            "64 MiB should pick a pipelined candidate, got {}",
            large.choice.label()
        );
        assert!(large.segments() > 1);
        assert_eq!(small.segments(), 1);
        assert!(large.sim_time < large.baseline_sim.unwrap());
        symexec::verify(large.schedule()).unwrap();
        // The schedule the decision carries is sized for the request.
        assert_eq!(large.schedule().msg.total_bytes, 64 << 20);
    }

    #[test]
    fn exhaustive_mode_simulates_everything() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg { shortlist: usize::MAX, ..TuneCfg::default() };
        let d = select(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        assert_eq!(d.simulated, d.considered);
    }

    #[test]
    fn graph_exchange_ops_report_no_candidates() {
        let cl = crate::topology::line(3, 2, 1);
        let pl = Placement::block(&cl);
        assert!(select(&cl, &pl, Collective::Allreduce, &TuneCfg::default()).is_err());
        // Dissemination ops still tune fine on graphs.
        select(&cl, &pl, Collective::Broadcast { root: 0 }, &TuneCfg::default()).unwrap();
    }

    #[test]
    fn selection_is_deterministic() {
        let cl = switched(6, 4, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let a = select(&cl, &pl, Collective::AllToAll, &cfg).unwrap();
        let b = select(&cl, &pl, Collective::AllToAll, &cfg).unwrap();
        assert_eq!(a.choice, b.choice);
        assert_eq!(a.sim_time, b.sim_time);
        assert_eq!(a.schedule, b.schedule);
    }

    #[test]
    fn robustness_off_by_default() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let d = select(&cl, &pl, Collective::Allreduce, &TuneCfg::default()).unwrap();
        assert_eq!(d.robust_sim, None);
    }

    #[test]
    fn robust_selection_keeps_clean_contract_and_degrades_no_worse() {
        let cl = switched(6, 4, 1);
        let pl = Placement::block(&cl);
        let coll = Collective::Broadcast { root: 0 };
        let clean = select(&cl, &pl, coll, &TuneCfg::default()).unwrap();
        let cfg = TuneCfg::default().with_robustness(3, 11, 16.0);
        let robust = select(&cl, &pl, coll, &cfg).unwrap();
        symexec::verify(robust.schedule()).unwrap();

        // Clean-run contract survives robust scoring.
        let base = robust.baseline_sim.expect("switch has a flat baseline");
        assert!(robust.sim_time <= base + 1e-12);
        // A straggler can only stretch the makespan.
        let rsim = robust.robust_sim.expect("robust scoring on");
        assert!(rsim >= robust.sim_time);

        // Replicate the tuner's draws: the robust pick's mean degraded
        // makespan must be <= the clean pick's under the same scenarios.
        let mut rng = Rng::seed_from_u64(11);
        let draws: Vec<usize> =
            (0..3).map(|_| rng.gen_range(0..cl.num_machines())).collect();
        let mean = |s: &Schedule| {
            let mut acc = 0.0;
            for &m in &draws {
                let p = TuneCfg::default().sim.with_slowdown(m, 16.0);
                acc += crate::sim::simulate(&cl, &pl, s, &p).unwrap().t_end / 3.0;
            }
            acc
        };
        assert_eq!(rsim, mean(robust.schedule()), "reported robust makespan");
        assert!(mean(robust.schedule()) <= mean(clean.schedule()) + 1e-12);
    }

    #[test]
    fn run_jobs_threaded_preserves_job_order() {
        // The threaded fan-out must land result i in slot i regardless of
        // scheduling, for both scratch flavors (unit for stage 1, arena
        // for stage 2).
        let unit: Vec<usize> = run_jobs(64, 4, || (), |_scratch, i| i * 3);
        assert_eq!(unit, (0..64).map(|i| i * 3).collect::<Vec<_>>());
        let with_arena: Vec<usize> =
            run_jobs(17, 3, SimArena::new, |_arena, i| i + 100);
        assert_eq!(with_arena, (100..117).collect::<Vec<_>>());
        // Degenerate shapes.
        assert!(run_jobs(0, 4, || (), |_s, i| i).is_empty());
        assert_eq!(run_jobs(3, 8, || (), |_s, i| i), vec![0, 1, 2]);
    }

    #[test]
    fn batched_matches_one_shot() {
        // select_many must hand back exactly what per-collective select
        // does, in input order — batching is an execution detail. (At
        // this size stage 1 stays below its parallel threshold and runs
        // inline; stage 2's pools cross theirs, so the threaded sweep is
        // exercised there — run_jobs_threaded_preserves_job_order covers
        // the threaded helper for both scratch flavors directly.)
        let cl = switched(8, 8, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let colls = [
            Collective::Broadcast { root: 0 },
            Collective::Allreduce,
            Collective::AllToAll,
            Collective::Gather { root: 3 },
        ];
        let batch = select_many(&cl, &pl, &colls, &cfg).unwrap();
        assert_eq!(batch.len(), colls.len());
        for (coll, batched) in colls.iter().zip(&batch) {
            let solo = select(&cl, &pl, *coll, &cfg).unwrap();
            assert_eq!(solo.choice, batched.choice, "{}", coll.name());
            assert_eq!(solo.sim_time, batched.sim_time, "{}", coll.name());
            assert_eq!(solo.schedule, batched.schedule, "{}", coll.name());
            assert_eq!(solo.baseline_sim, batched.baseline_sim, "{}", coll.name());
            assert_eq!(solo.model_cost, batched.model_cost, "{}", coll.name());
        }
    }

    #[test]
    fn quotient_matches_full_materialization() {
        // On uniform grids below the cap the quotient path must make the
        // *same* decision as full materialization — same winner, same
        // schedule, same audited numbers — because the analytic ranking
        // is bit-exact and the stage-2 pool is identical.
        for (m, c, n) in [(4, 4, 2), (8, 8, 2), (6, 4, 1), (16, 8, 4)] {
            let cl = switched(m, c, n);
            let pl = Placement::block(&cl);
            for coll in [Collective::Broadcast { root: 0 }, Collective::Allreduce] {
                for cfg in [
                    TuneCfg::default(),
                    TuneCfg::default().with_msg_bytes(64 << 20),
                    TuneCfg::default().with_robustness(2, 7, 8.0),
                ] {
                    let q = select(&cl, &pl, coll, &cfg).unwrap();
                    let f =
                        select(&cl, &pl, coll, &cfg.clone().with_quotient(false)).unwrap();
                    let tag = format!("{m}x{c}x{n} {}", coll.name());
                    assert_eq!(q.choice, f.choice, "{tag}");
                    assert_eq!(q.schedule, f.schedule, "{tag}");
                    assert_eq!(q.model_cost, f.model_cost, "{tag}");
                    assert_eq!(q.sim_time, f.sim_time, "{tag}");
                    assert_eq!(q.baseline_sim, f.baseline_sim, "{tag}");
                    assert_eq!(q.robust_sim, f.robust_sim, "{tag}");
                    assert_eq!(q.considered, f.considered, "{tag}");
                    assert_eq!(q.simulated, f.simulated, "{tag}");
                }
            }
        }
    }

    #[test]
    fn quotient_above_cap_skips_materialization() {
        // 1024 machines x 16 cores = 16384 ranks, above the default cap:
        // the decision comes back without a schedule (the whole point),
        // with a representative-grid confirmation behind it.
        let cl = switched(1024, 16, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let d = select(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        assert!(d.schedule.is_none());
        assert!(d.baseline_sim.is_some());
        assert!(d.sim_time > 0.0);
        assert!(d.considered > 0 && d.simulated > 0);
        // Materialize-on-demand produces a verified, request-sized
        // schedule for the winning candidate on the real topology.
        let s = d.materialize(&cl, &pl, &cfg).unwrap();
        symexec::verify(&s).unwrap();
        assert_eq!(s.msg.total_bytes, cfg.msg_bytes);
    }

    #[test]
    fn quotient_representative_pick_matches_full_tuning_where_checkable() {
        // Force the representative path on a grid small enough to also
        // tune exhaustively: a tiny cap pushes 8x4 (32 ranks) above the
        // materialization ceiling while its 4x4 representative still
        // fits. The representative winner must match the full tuner's.
        let cl = switched(8, 4, 2);
        let pl = Placement::block(&cl);
        let mut cfg = TuneCfg::default();
        cfg.quotient_sim_cap = 16;
        let d = select(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
        assert!(d.schedule.is_none());
        let full = select(&cl, &pl, Collective::Allreduce, &TuneCfg::default()).unwrap();
        assert_eq!(d.choice, full.choice);
    }

    #[test]
    fn quotient_ignores_irregular_and_non_block_layouts() {
        // Irregular topology: quotient ineligible, classic path carries
        // the schedule even with the flag on.
        let cl = crate::topology::line(3, 2, 1);
        let pl = Placement::block(&cl);
        let d = select(&cl, &pl, Collective::Broadcast { root: 0 }, &TuneCfg::default())
            .unwrap();
        assert!(d.schedule.is_some());
        // Uniform grid but round-robin placement: same story.
        let cl = switched(4, 4, 2);
        let rr = Placement::round_robin(&cl);
        let d = select(&cl, &rr, Collective::Allreduce, &TuneCfg::default()).unwrap();
        assert!(d.schedule.is_some());
    }

    #[test]
    fn batched_rejects_any_unbuildable_collective() {
        let cl = crate::topology::line(3, 2, 1);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        // Allreduce has no graph builder: the whole batch errors.
        assert!(select_many(
            &cl,
            &pl,
            &[Collective::Broadcast { root: 0 }, Collective::Allreduce],
            &cfg
        )
        .is_err());
    }
}
