//! Two-stage candidate selection: rank by round-model cost, break ties
//! (and confirm) with the continuous-time simulator.
//!
//! Stage 1 prices every applicable candidate under the configured
//! [`Multicore`] model — cheap, round-based, and already enough to
//! discard grossly oversubscribed schedules (flat candidates are
//! legalized first, exactly as a real NIC-constrained cluster would
//! serialize them). The best [`TuneCfg::shortlist`] candidates advance.
//!
//! Stage 2 runs the shortlist through [`crate::sim::simulate`] and picks
//! the smallest simulated completion time. The flat baseline
//! ([`crate::tune::flat_baseline`]) is *always* added to stage 2 when the
//! topology admits one, which yields the tuner's contract:
//!
//! > **`select` never returns a schedule whose simulated time exceeds the
//! > flat baseline's.**
//!
//! Ties are broken by model cost, then candidate label, so selection is
//! fully deterministic.

use crate::model::{legalize, CostModel, Multicore};
use crate::sched::Schedule;
use crate::sim::{simulate, SimParams};
use crate::topology::{Cluster, Placement};

use super::registry::{candidates_for, flat_baseline, CandidateId, Collective};

/// Tuner configuration: the cost model used for stage-1 ranking (its
/// duplex assumption and `alpha` are part of the cache fingerprint), the
/// simulator physics used for stage-2 confirmation, and the shortlist
/// width.
#[derive(Debug, Clone)]
pub struct TuneCfg {
    pub model: Multicore,
    pub sim: SimParams,
    /// How many stage-1 winners advance to simulation. Larger values
    /// trade tuning time for decision quality; `usize::MAX` simulates
    /// every candidate (exhaustive mode, used by ablations).
    pub shortlist: usize,
}

impl Default for TuneCfg {
    fn default() -> Self {
        Self {
            model: Multicore::default(),
            sim: SimParams::lan_cluster(16 << 10),
            shortlist: 4,
        }
    }
}

/// The outcome of one tuning run: the winning schedule plus enough
/// context to audit the choice.
#[derive(Debug, Clone)]
pub struct Decision {
    pub choice: CandidateId,
    /// The winning schedule, legalized for `cfg.model` if the raw builder
    /// output was not already legal.
    pub schedule: Schedule,
    /// Stage-1 scalar cost of the winner (`ext + alpha * int`).
    pub model_cost: f64,
    /// Stage-2 simulated completion time of the winner, seconds.
    pub sim_time: f64,
    /// Simulated time of the flat baseline, when the topology admits one.
    pub baseline_sim: Option<f64>,
    /// Candidates priced in stage 1 / simulated in stage 2.
    pub considered: usize,
    pub simulated: usize,
}

impl Decision {
    /// Fractional improvement over the flat baseline (0.37 = 37% faster),
    /// when a baseline exists.
    pub fn win_margin(&self) -> Option<f64> {
        self.baseline_sim
            .map(|b| if b > 0.0 { 1.0 - self.sim_time / b } else { 0.0 })
    }
}

/// Select the best schedule for `collective` on this topology. See the
/// module docs for the two-stage procedure and the baseline guarantee.
pub fn select(
    cluster: &Cluster,
    placement: &Placement,
    collective: Collective,
    cfg: &TuneCfg,
) -> crate::Result<Decision> {
    let ids = candidates_for(collective, cluster, placement);
    if ids.is_empty() {
        anyhow::bail!(
            "no applicable schedule builder for {} on this topology \
             (exchange-style collectives need a switched interconnect)",
            collective.name()
        );
    }

    // Stage 1: build, legalize if needed, price under the round model.
    let mut ranked: Vec<(CandidateId, Schedule, f64)> = Vec::with_capacity(ids.len());
    for id in ids {
        let built = id.build(cluster, placement)?;
        let schedule = if cfg.model.validate(cluster, placement, &built).is_ok() {
            built
        } else {
            legalize(&cfg.model, cluster, placement, &built)
        };
        let cost = cfg.model.cost(cluster, placement, &schedule)?;
        ranked.push((id, schedule, cost));
    }
    let considered = ranked.len();
    ranked.sort_by(|a, b| {
        a.2.partial_cmp(&b.2)
            .expect("model costs are finite")
            .then_with(|| a.0.label().cmp(&b.0.label()))
    });

    // Stage 2 pool: shortlist plus (always) the flat baseline.
    let baseline = flat_baseline(collective, cluster);
    let cut = cfg.shortlist.clamp(1, ranked.len());
    let mut pool: Vec<(CandidateId, Schedule, f64)> = Vec::with_capacity(cut + 1);
    let mut rest: Vec<(CandidateId, Schedule, f64)> = Vec::new();
    for (i, entry) in ranked.into_iter().enumerate() {
        if i < cut {
            pool.push(entry);
        } else {
            rest.push(entry);
        }
    }
    if let Some(b) = baseline {
        if !pool.iter().any(|(id, _, _)| *id == b) {
            if let Some(p) = rest.iter().position(|(id, _, _)| *id == b) {
                pool.push(rest.swap_remove(p));
            }
        }
    }

    // Stage 2: simulate the pool, keep the fastest (ties: model cost,
    // then label — deterministic).
    let mut sims = Vec::with_capacity(pool.len());
    let mut baseline_sim = None;
    for (id, schedule, _) in &pool {
        let t = simulate(cluster, placement, schedule, &cfg.sim)?.t_end;
        if baseline == Some(*id) {
            baseline_sim = Some(t);
        }
        sims.push(t);
    }
    let mut best = 0usize;
    for i in 1..pool.len() {
        let a = (sims[i], pool[i].2, pool[i].0.label());
        let b = (sims[best], pool[best].2, pool[best].0.label());
        if a < b {
            best = i;
        }
    }
    let simulated = pool.len();
    let (choice, schedule, model_cost) = pool.swap_remove(best);
    Ok(Decision {
        choice,
        schedule,
        model_cost,
        sim_time: sims[best],
        baseline_sim,
        considered,
        simulated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::symexec;
    use crate::topology::{switched, Placement};
    use crate::tune::Collective;

    #[test]
    fn broadcast_on_fat_cluster_prefers_mc_aware() {
        // 16 machines x 8 cores x 4 NICs: the paper's regime where
        // (k+1)^t dissemination crushes the binomial tree.
        let cl = switched(16, 8, 4);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let d = select(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        symexec::verify(&d.schedule).unwrap();
        assert!(
            matches!(d.choice, CandidateId::BcastMcAware { .. }),
            "expected mc-aware, got {}",
            d.choice.label()
        );
        let base = d.baseline_sim.expect("switch has a flat baseline");
        assert!(d.sim_time <= base, "tuned {} > baseline {base}", d.sim_time);
        assert!(d.win_margin().unwrap() > 0.0);
    }

    #[test]
    fn single_machine_broadcast_is_one_write() {
        let cl = switched(1, 8, 1);
        let pl = Placement::block(&cl);
        let d = select(&cl, &pl, Collective::Broadcast { root: 0 }, &TuneCfg::default())
            .unwrap();
        assert_eq!(d.schedule.external_messages(), 0);
        assert!(d.sim_time <= d.baseline_sim.unwrap());
    }

    #[test]
    fn allreduce_selects_and_beats_baseline() {
        let cl = switched(4, 8, 4);
        let pl = Placement::block(&cl);
        let d = select(&cl, &pl, Collective::Allreduce, &TuneCfg::default()).unwrap();
        symexec::verify(&d.schedule).unwrap();
        assert!(d.sim_time <= d.baseline_sim.unwrap());
        assert!(d.considered >= 4);
        assert!(d.simulated <= d.considered);
    }

    #[test]
    fn exhaustive_mode_simulates_everything() {
        let cl = switched(4, 4, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg { shortlist: usize::MAX, ..TuneCfg::default() };
        let d = select(&cl, &pl, Collective::Broadcast { root: 0 }, &cfg).unwrap();
        assert_eq!(d.simulated, d.considered);
    }

    #[test]
    fn graph_exchange_ops_report_no_candidates() {
        let cl = crate::topology::line(3, 2, 1);
        let pl = Placement::block(&cl);
        assert!(select(&cl, &pl, Collective::Allreduce, &TuneCfg::default()).is_err());
        // Dissemination ops still tune fine on graphs.
        select(&cl, &pl, Collective::Broadcast { root: 0 }, &TuneCfg::default()).unwrap();
    }

    #[test]
    fn selection_is_deterministic() {
        let cl = switched(6, 4, 2);
        let pl = Placement::block(&cl);
        let cfg = TuneCfg::default();
        let a = select(&cl, &pl, Collective::AllToAll, &cfg).unwrap();
        let b = select(&cl, &pl, Collective::AllToAll, &cfg).unwrap();
        assert_eq!(a.choice, b.choice);
        assert_eq!(a.sim_time, b.sim_time);
        assert_eq!(a.schedule, b.schedule);
    }
}
