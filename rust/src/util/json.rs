//! Minimal JSON parser (std-only; the offline build has no serde_json).
//!
//! Supports the full JSON grammar minus exotic escapes (`\uXXXX` is
//! decoded for the BMP). Used for `artifacts/meta.json` and experiment
//! config files.

use std::collections::HashMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> crate::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Fetch a required integer field from an object.
    pub fn req_usize(&self, key: &str) -> crate::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid field {key:?}"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> crate::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => anyhow::bail!("unexpected byte at {}", self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> crate::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => anyhow::bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.eat(b'{')?;
        let mut out = HashMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => anyhow::bail!("expected , or }} at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_style_object() {
        let j = Json::parse(r#"{"num_params": 469504, "batch": 16, "name": "x"}"#)
            .unwrap();
        assert_eq!(j.req_usize("num_params").unwrap(), 469504);
        assert_eq!(j.get("name").unwrap().as_str(), Some("x"));
        assert!(j.req_usize("missing").is_err());
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}}"#)
            .unwrap();
        match j.get("a") {
            Some(Json::Arr(v)) => {
                assert_eq!(v[0].as_f64(), Some(1.0));
                assert_eq!(v[2].as_f64(), Some(-300.0));
            }
            _ => panic!(),
        }
        assert_eq!(j.get("b").unwrap().get("c"), Some(&Json::Bool(true)));
        assert_eq!(j.get("b").unwrap().get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\n\"b\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\"b\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo — ok""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo — ok"));
    }
}
