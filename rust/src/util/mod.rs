//! Small self-contained utilities (the build environment is offline, so
//! the crate is std-only: PRNG, stats and table formatting live here).

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
