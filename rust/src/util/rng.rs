//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Not cryptographic — used only for reproducible workload/topology
//! generation and the in-tree property-testing harness. Same seed ⇒ same
//! stream on every platform.

/// xoshiro256** (Blackman & Vigna), SplitMix64-seeded.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 to fill the state (never all-zero).
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Uniform usize in [lo, hi) — Lemire-ish rejection-free (modulo bias
    /// is irrelevant for our range sizes, but keep it unbiased anyway).
    pub fn gen_range(&mut self, range: std::ops::Range<usize>) -> usize {
        assert!(range.start < range.end, "empty range");
        let span = (range.end - range.start) as u64;
        // Rejection sampling to remove modulo bias.
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return range.start + (v % span) as usize;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0..i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(0..xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = r.gen_range(10..15);
            assert!((10..15).contains(&v));
            seen[v - 10] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit in 1000 draws");
    }

    #[test]
    fn gen_bool_rate_sane() {
        let mut r = Rng::seed_from_u64(3);
        let hits = (0..10_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((2600..3400).contains(&hits), "got {hits}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::seed_from_u64(4);
        let mut xs: Vec<usize> = (0..20).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(xs, (0..20).collect::<Vec<_>>()); // astronomically unlikely
    }
}
