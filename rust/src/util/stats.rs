//! Tiny statistics helpers for experiment reports.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean (0 for empty input; requires positive values).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Trimmed mean: drop the lowest and highest `frac` of samples (rounded
/// down, at least 0) and average the middle. The calibration runner's
/// robust statistic — outliers from scheduler noise on loaded hosts fall
/// off both ends. `frac` in [0, 0.5); empty input yields 0.
pub fn trimmed_mean(xs: &[f64], frac: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cut = ((v.len() as f64) * frac.clamp(0.0, 0.49)) as usize;
    let kept = &v[cut..v.len() - cut];
    mean(kept)
}

/// p-th percentile (nearest-rank, p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0 * v.len() as f64).ceil() as usize).clamp(1, v.len());
    v[idx - 1]
}

/// Spearman rank correlation between two equal-length series — used by E6
/// to check that model cost, simulated time and real executor time agree
/// on *ordering* even when absolute scales differ.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

/// Pearson correlation.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (ma, mb) = (mean(a), mean(b));
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        num += (a[i] - ma) * (b[i] - mb);
        da += (a[i] - ma).powi(2);
        db += (b[i] - mb).powi(2);
    }
    if da == 0.0 || db == 0.0 {
        return 0.0;
    }
    num / (da * db).sqrt()
}

/// Fractional ranks with tie averaging.
fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            out[idx[k]] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_drops_tails() {
        // 25% trim on 8 samples drops 2 from each end.
        let xs = [100.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, -50.0];
        assert!((trimmed_mean(&xs, 0.25) - 3.5).abs() < 1e-12);
        // No trim = plain mean; empty input is 0.
        assert_eq!(trimmed_mean(&[2.0, 4.0], 0.0), 3.0);
        assert_eq!(trimmed_mean(&[], 0.25), 0.0);
        // Tiny samples never trim everything away.
        assert_eq!(trimmed_mean(&[7.0], 0.4), 7.0);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 1.0), 1.0);
    }

    #[test]
    fn spearman_perfect_and_inverse() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        let c = [40.0, 30.0, 20.0, 10.0];
        assert!((spearman(&a, &c) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear_is_one() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 8.0, 27.0, 64.0, 125.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_average_ties() {
        let r = ranks(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }
}
