//! Markdown-ish table printer for experiment harnesses: every experiment
//! binary prints rows comparable to the paper's claims through this.

/// A simple left-aligned text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Self {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float compactly (3 significant decimals, engineering-friendly).
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else if x.abs() >= 1e-3 {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

/// Format seconds with an adaptive unit.
pub fn ftime(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["a", "long_header"]);
        t.row(vec!["1", "2"]);
        t.row(vec!["333333", "4"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(r.contains("long_header"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1"]);
    }

    #[test]
    fn formats() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.0), "1234");
        assert_eq!(fnum(1.5), "1.50");
        assert_eq!(ftime(0.002), "2.000ms");
        assert_eq!(ftime(2.5e-6), "2.500us");
    }
}
