//! Differential gate for the symmetry quotient: every closed form in
//! [`mcomm::model::analytic`] must be **bit-exact** against the cost of
//! the schedule it prices — built by the real builder, legalized when
//! the raw schedule oversubscribes NICs, lowered, and tallied by
//! `Multicore::cost_detail_lowered`. Field-by-field `McCost` equality,
//! with the `f64` fields compared by bit pattern: the quotient fast
//! path substitutes analytic numbers for materialized ones inside the
//! selector's ranking, so "close" is not good enough — a single ULP of
//! drift could flip a shortlist cut.
//!
//! Three legs:
//!  1. analytic == materialized `cost_detail_lowered`, swept over
//!     grids (including M=1, C=1, non-powers of two), NIC counts,
//!     payload sizes (zero, odd, uneven-split), byte-weight models,
//!     and segment counts;
//!  2. quotient-enabled `tune::select` == full-materialization
//!     `tune::select` (same pick, same bit-level scores, same
//!     schedule) on uniform grids up to 256 ranks, with the winner's
//!     `sim_time` replayed against an independent simulation;
//!  3. above-cap decisions materialize on demand into schedules that
//!     pass symbolic execution and model validation.

use mcomm::model::{legalize, Duplex, McCost, Multicore, UniformGrid};
use mcomm::model::CostModel;
use mcomm::sched::{symexec, LoweredSchedule, TopoCtx};
use mcomm::sim::simulate;
use mcomm::topology::{switched, Cluster, Placement};
use mcomm::tune::{
    self, analytic_cost, candidates_for, has_analytic, CandidateId, Collective,
    SegBase, TuneCfg,
};

/// The selector's `build_and_price` materialization, replicated exactly:
/// build, size, try the raw schedule, legalize on rejection.
fn materialized_detail(
    model: &Multicore,
    cl: &Cluster,
    pl: &Placement,
    id: CandidateId,
    bytes: u64,
) -> McCost {
    let ctx = TopoCtx::new(cl, pl);
    let mut built = id.build(cl, pl).expect("builder");
    built.set_total_bytes(bytes);
    if let Ok(low) = LoweredSchedule::compile(&ctx, &built) {
        if let Ok(d) = model.cost_detail_lowered(&low) {
            return d;
        }
    }
    let legal = legalize(model, cl, pl, &built);
    let low = LoweredSchedule::compile(&ctx, &legal).expect("legalized compiles");
    model.cost_detail_lowered(&low).expect("legalized is legal")
}

fn assert_cost_eq(analytic: &McCost, materialized: &McCost, ctx: &str) {
    assert_eq!(
        analytic.ext_rounds, materialized.ext_rounds,
        "{ctx}: ext_rounds"
    );
    assert_eq!(analytic.int_units, materialized.int_units, "{ctx}: int_units");
    assert_eq!(
        analytic.ext_messages, materialized.ext_messages,
        "{ctx}: ext_messages"
    );
    assert_eq!(
        analytic.ext_byte_units.to_bits(),
        materialized.ext_byte_units.to_bits(),
        "{ctx}: ext_byte_units {} vs {}",
        analytic.ext_byte_units,
        materialized.ext_byte_units,
    );
    assert_eq!(
        analytic.int_weighted.to_bits(),
        materialized.int_weighted.to_bits(),
        "{ctx}: int_weighted {} vs {}",
        analytic.int_weighted,
        materialized.int_weighted,
    );
}

/// Grid sweep: degenerate (1×1), single-machine many-core, single-core
/// many-machine, powers of two (the butterfly premise), and ragged
/// shapes whose uneven chunk splits stress `MsgSpec` arithmetic.
const GRIDS: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 4, 2),
    (1, 8, 1),
    (2, 1, 1),
    (2, 3, 1),
    (2, 8, 2),
    (3, 4, 2),
    (4, 4, 1),
    (4, 4, 4),
    (5, 3, 2),
    (8, 2, 2),
    (4, 8, 3),
];

/// Zero bytes (pure round counting), odd bytes (uneven `div_ceil`
/// splits), a block size, and a large odd payload.
const BYTES: &[u64] = &[0, 1337, 16 << 10, (1 << 20) + 7];

fn models() -> Vec<(&'static str, Multicore)> {
    vec![
        ("default", Multicore::default()),
        ("rounds_only", Multicore::rounds_only()),
        (
            "custom",
            Multicore {
                duplex: Duplex::Full,
                alpha: 0.25,
                byte_ext: 3.0e-9,
                byte_int: 5.0e-10,
            },
        ),
    ]
}

/// Leg 1: every registered candidate with a closed form, across the
/// full grid × payload × model sweep. Also pins the coverage invariant
/// the fast path relies on: on uniform grids, *every* broadcast and
/// allreduce candidate has an analytic form (one gap would silently
/// disable the quotient for the whole collective).
#[test]
fn analytic_forms_match_materialized_costs() {
    for &(m, c, n) in GRIDS {
        let cl = switched(m, c, n);
        let pl = Placement::block(&cl);
        let grid = UniformGrid::new(m, c, n);
        for coll in [Collective::Broadcast { root: 0 }, Collective::Allreduce] {
            let ids = candidates_for(coll, &cl, &pl);
            assert!(
                ids.iter().all(|&id| has_analytic(id)),
                "({m}x{c},k={n}) {}: a candidate lacks an analytic form",
                coll.name()
            );
            for id in ids {
                for (mname, model) in models() {
                    for &bytes in BYTES {
                        let analytic = analytic_cost(id, &model, grid, bytes)
                            .unwrap_or_else(|| {
                                panic!("({m}x{c},k={n}) {}: no analytic cost", id.label())
                            });
                        let detail = materialized_detail(&model, &cl, &pl, id, bytes);
                        let ctx = format!(
                            "({m}x{c},k={n}) {} {mname} {bytes}B",
                            id.label()
                        );
                        assert_cost_eq(&analytic, &detail, &ctx);
                    }
                }
            }
        }
    }
}

/// Leg 1, segment-count extension: the registry only sweeps segment
/// counts 2 for the allreduce ring, but the closed form claims all
/// counts — check 2, 4, 8 for both segmented families directly.
#[test]
fn segmented_forms_match_across_segment_counts() {
    let model = Multicore::default();
    for &(m, c, n) in &[(2usize, 3usize, 1usize), (3, 4, 2), (4, 4, 4), (1, 6, 2)] {
        let cl = switched(m, c, n);
        let pl = Placement::block(&cl);
        let grid = UniformGrid::new(m, c, n);
        for segments in [2u32, 4, 8] {
            for base in [
                SegBase::BcastChainMc { root: 0 },
                SegBase::AllreduceRing,
            ] {
                let id = CandidateId::Segmented { base, segments };
                for &bytes in &[1337u64, (1 << 20) + 7] {
                    let analytic = analytic_cost(id, &model, grid, bytes)
                        .expect("segmented closed form");
                    let detail = materialized_detail(&model, &cl, &pl, id, bytes);
                    let ctx =
                        format!("({m}x{c},k={n}) {} {bytes}B", id.label());
                    assert_cost_eq(&analytic, &detail, &ctx);
                }
            }
        }
    }
}

/// Leg 1, root symmetry: the quotient accepts any machine-leader root;
/// the closed forms must hold at a non-zero leader too.
#[test]
fn analytic_forms_hold_at_nonzero_leader_root() {
    let (m, c, n) = (3usize, 4usize, 2usize);
    let cl = switched(m, c, n);
    let pl = Placement::block(&cl);
    let grid = UniformGrid::new(m, c, n);
    let model = Multicore::default();
    let root = c; // leader of machine 1
    for id in candidates_for(Collective::Broadcast { root }, &cl, &pl) {
        let analytic =
            analytic_cost(id, &model, grid, 16 << 10).expect("closed form");
        let detail = materialized_detail(&model, &cl, &pl, id, 16 << 10);
        assert_cost_eq(&analytic, &detail, &format!("root {root} {}", id.label()));
    }
}

/// Leg 2: quotient-enabled selection is indistinguishable from full
/// materialization on every uniform grid up to 256 ranks — same pick,
/// bit-identical scores, identical schedule — and the winner's reported
/// `sim_time` bit-matches an independent simulation replay.
#[test]
fn quotient_select_agrees_with_full_materialization_up_to_256_ranks() {
    let quotient = TuneCfg::default();
    let full = TuneCfg::default().with_quotient(false);
    let grids: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 16, 2),
        (2, 2, 1),
        (2, 4, 2),
        (3, 3, 1),
        (4, 4, 2),
        (4, 8, 1),
        (5, 5, 2),
        (8, 8, 2),
        (16, 4, 1),
        (16, 16, 2),
        (32, 8, 4),
    ];
    for &(m, c, n) in grids {
        let cl = switched(m, c, n);
        let pl = Placement::block(&cl);
        assert!(pl.num_ranks() <= 256, "sweep outgrew its own premise");
        for coll in [Collective::Broadcast { root: 0 }, Collective::Allreduce] {
            let ctx = format!("({m}x{c},k={n}) {}", coll.name());
            let q = tune::select(&cl, &pl, coll, &quotient).unwrap();
            let f = tune::select(&cl, &pl, coll, &full).unwrap();
            assert_eq!(q.choice, f.choice, "{ctx}: pick diverged");
            assert_eq!(
                q.model_cost.to_bits(),
                f.model_cost.to_bits(),
                "{ctx}: model_cost {} vs {}",
                q.model_cost,
                f.model_cost
            );
            assert_eq!(
                q.sim_time.to_bits(),
                f.sim_time.to_bits(),
                "{ctx}: sim_time {} vs {}",
                q.sim_time,
                f.sim_time
            );
            assert_eq!(
                q.baseline_sim.map(f64::to_bits),
                f.baseline_sim.map(f64::to_bits),
                "{ctx}: baseline_sim"
            );
            assert_eq!(q.considered, f.considered, "{ctx}: considered");
            assert_eq!(q.simulated, f.simulated, "{ctx}: simulated");
            assert_eq!(
                q.schedule(),
                f.schedule(),
                "{ctx}: materialized schedules diverged"
            );
            // The third leg of the differential: the decision's score IS
            // the simulated makespan of the schedule it carries.
            let replay =
                simulate(&cl, &pl, q.schedule(), &quotient.sim).unwrap().t_end;
            assert_eq!(
                q.sim_time.to_bits(),
                replay.to_bits(),
                "{ctx}: sim_time {} != replayed makespan {replay}",
                q.sim_time
            );
        }
    }
}

/// Leg 3: above the simulation cap the decision ships without a
/// schedule; `materialize` must still produce a semantically correct,
/// model-legal schedule for the analytically chosen algorithm.
#[test]
fn above_cap_decision_materializes_verified_schedule() {
    let cl = switched(64, 8, 2); // 512 ranks
    let pl = Placement::block(&cl);
    let mut cfg = TuneCfg::default();
    cfg.quotient_sim_cap = 64; // 512 > 64, representative 4x8=32 <= 64
    for coll in [Collective::Broadcast { root: 0 }, Collective::Allreduce] {
        let d = tune::select(&cl, &pl, coll, &cfg).unwrap();
        assert!(
            has_analytic(d.choice),
            "{}: representative pick lacks analytic form",
            coll.name()
        );
        let s = d.materialize(&cl, &pl, &cfg).unwrap();
        symexec::verify(&s).unwrap();
        cfg.model.validate(&cl, &pl, &s).unwrap();
    }
}
