//! Concurrency stress tests for the sharded decision cache (satellite of
//! the tuning-as-a-service PR): many threads hammering one
//! [`DecisionCache`] / [`Tuned`] must observe exactly the decisions a
//! single-threaded run would, no matter how the races land.
//!
//! The determinism argument being exercised: selection is a pure
//! function of (topology, collective, cfg), so when two threads race to
//! tune the same fingerprint both compute bit-identical decisions and
//! the insert path's double-probe makes the loser adopt the winner's
//! entry. These tests would catch torn decisions, lost inserts, counter
//! drift, and eviction/invalidation races.

use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::Arc;

use mcomm::topology::{switched, Cluster, Placement};
use mcomm::tune::{
    CacheConfig, Collective, Decision, DecisionCache, Fingerprint, TuneCfg,
};
use mcomm::util::Rng;

const THREADS: usize = 8;

/// The overlapping query universe: small topologies (tunes stay cheap)
/// crossed with collectives and two payload size classes.
fn universe() -> Vec<(Cluster, Placement, Collective, TuneCfg)> {
    let mut out = Vec::new();
    for (m, c) in [(2usize, 2usize), (3, 2), (2, 4)] {
        let cl = switched(m, c, 1);
        let pl = Placement::block(&cl);
        for coll in [Collective::Broadcast { root: 0 }, Collective::Allreduce] {
            for msg_bytes in [4u64 << 10, 64 << 10] {
                let cfg = TuneCfg::default().with_msg_bytes(msg_bytes);
                out.push((cl.clone(), pl.clone(), coll, cfg));
            }
        }
    }
    out
}

/// Bit-exact decision equality: every field, floats compared by bits.
fn assert_identical(got: &Decision, want: &Decision, ctx: &str) {
    assert_eq!(got.choice, want.choice, "{ctx}: choice");
    assert_eq!(got.schedule, want.schedule, "{ctx}: schedule");
    assert_eq!(
        got.model_cost.to_bits(),
        want.model_cost.to_bits(),
        "{ctx}: model_cost"
    );
    assert_eq!(got.sim_time.to_bits(), want.sim_time.to_bits(), "{ctx}: sim_time");
    assert_eq!(
        got.baseline_sim.map(f64::to_bits),
        want.baseline_sim.map(f64::to_bits),
        "{ctx}: baseline_sim"
    );
    assert_eq!(
        got.robust_sim.map(f64::to_bits),
        want.robust_sim.map(f64::to_bits),
        "{ctx}: robust_sim"
    );
    assert_eq!(
        (got.considered, got.simulated),
        (want.considered, want.simulated),
        "{ctx}: candidate counts"
    );
}

#[test]
fn concurrent_get_or_tune_is_bit_identical_to_single_threaded() {
    let uni = universe();
    // Single-threaded reference: one cold tune per key.
    let reference: Vec<Arc<Decision>> = {
        let cache = DecisionCache::new();
        uni.iter()
            .map(|(cl, pl, coll, cfg)| cache.get_or_tune(cl, pl, *coll, cfg).unwrap())
            .collect()
    };

    let cache = DecisionCache::new();
    let queries_per_thread = 60;
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let cache = &cache;
            let uni = &uni;
            let reference = &reference;
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(0xD1CE + t as u64);
                for q in 0..queries_per_thread {
                    // First lap: stride the universe so every key is
                    // queried by every thread (maximal overlap, full
                    // coverage); then random Zipf-free hammering.
                    let i = if q < uni.len() {
                        (q + t) % uni.len()
                    } else {
                        rng.gen_range(0..uni.len())
                    };
                    let (cl, pl, coll, cfg) = &uni[i];
                    let d = cache.get_or_tune(cl, pl, *coll, cfg).unwrap();
                    assert_identical(&d, &reference[i], "racing get_or_tune");
                }
            });
        }
    });

    let s = cache.stats();
    assert_eq!(
        s.hits + s.misses,
        THREADS * queries_per_thread,
        "every query is either a hit or a miss"
    );
    assert_eq!(s.entries, uni.len(), "exactly one live entry per key");
    assert!(
        s.misses >= uni.len(),
        "each key misses at least once ({} keys, {} misses)",
        uni.len(),
        s.misses
    );
    assert_eq!(s.evictions, 0, "default capacity never evicts here");
    assert_eq!(s.per_shard.iter().sum::<usize>(), s.entries);

    // Post-quiescence, every key is resident and identical to the
    // reference (no lost inserts, no torn entries).
    for ((cl, pl, coll, cfg), want) in uni.iter().zip(&reference) {
        let fp = Fingerprint::new(cl, pl, *coll, cfg);
        let d = cache.lookup(&fp).expect("key resident after the stampede");
        assert_identical(&d, want, "post-quiescence lookup");
    }
}

#[test]
fn concurrent_eviction_never_starves_the_returning_thread() {
    // Capacity far below the working set: every thread keeps evicting
    // everyone else's entries. The contract under that churn: each call
    // still returns the right (bit-identical) decision, and the entry a
    // call just inserted was resident when the call returned (eviction
    // runs before insertion, so a thread can never victimize the entry
    // it is about to return).
    let uni = universe();
    let reference: Vec<Arc<Decision>> = {
        let cache = DecisionCache::new();
        uni.iter()
            .map(|(cl, pl, coll, cfg)| cache.get_or_tune(cl, pl, *coll, cfg).unwrap())
            .collect()
    };

    let cache = DecisionCache::with_config(CacheConfig { shards: 2, capacity: 4 });
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let cache = &cache;
            let uni = &uni;
            let reference = &reference;
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(0xE71C + t as u64);
                for _ in 0..40 {
                    let i = rng.gen_range(0..uni.len());
                    let (cl, pl, coll, cfg) = &uni[i];
                    let d = cache.get_or_tune(cl, pl, *coll, cfg).unwrap();
                    assert_identical(&d, &reference[i], "eviction-pressure query");
                }
            });
        }
    });

    let s = cache.stats();
    assert!(s.entries <= 4, "capacity bound holds: {} entries", s.entries);
    assert!(s.evictions > 0, "working set exceeds capacity: churn expected");
    // Every slab insert either grew the cache or evicted a victim; a
    // miss that lost the double-tune race adopts the winner's entry
    // without inserting, so misses bounds the sum from above.
    assert!(
        s.misses >= s.evictions + s.entries + s.invalidations,
        "occupancy reconciles with the counters: {s:?}"
    );
}

#[test]
fn invalidate_under_contention_stays_coherent() {
    let uni = universe();
    let reference: Vec<Arc<Decision>> = {
        let cache = DecisionCache::new();
        uni.iter()
            .map(|(cl, pl, coll, cfg)| cache.get_or_tune(cl, pl, *coll, cfg).unwrap())
            .collect()
    };
    let fps: Vec<Fingerprint> = uni
        .iter()
        .map(|(cl, pl, coll, cfg)| Fingerprint::new(cl, pl, *coll, cfg))
        .collect();

    let cache = DecisionCache::new();
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for t in 0..THREADS - 1 {
            let cache = &cache;
            let uni = &uni;
            let reference = &reference;
            let stop = &stop;
            s.spawn(move || {
                let mut rng = Rng::seed_from_u64(0x1117 + t as u64);
                let mut n = 0usize;
                // Keep querying until the invalidator finishes, with a
                // floor so the test exercises contention even if the
                // invalidator wins the scheduling lottery.
                while n < 30 || !stop.load(Relaxed) {
                    let i = rng.gen_range(0..uni.len());
                    let (cl, pl, coll, cfg) = &uni[i];
                    let d = cache.get_or_tune(cl, pl, *coll, cfg).unwrap();
                    assert_identical(&d, &reference[i], "query under invalidation");
                    n += 1;
                }
            });
        }
        let cache = &cache;
        let fps = &fps;
        let stop = &stop;
        s.spawn(move || {
            let mut rng = Rng::seed_from_u64(0xDEAD);
            for _ in 0..60 {
                let fp = &fps[rng.gen_range(0..fps.len())];
                // May or may not find the entry resident; both are legal.
                cache.invalidate(fp);
                std::thread::yield_now();
            }
            stop.store(true, Relaxed);
        });
    });

    let s = cache.stats();
    assert_eq!(s.per_shard.iter().sum::<usize>(), s.entries);
    assert!(s.entries <= uni.len());
    // Conservation: every slab insert is a miss (racing misses that
    // adopted an existing entry inserted nothing), nothing evicts at
    // default capacity, and only successful invalidations removed.
    assert_eq!(s.evictions, 0);
    assert!(
        s.entries + s.invalidations <= s.misses,
        "occupancy reconciles with the counters: {s:?}"
    );
    // The cache still serves every key correctly after the storm.
    for ((cl, pl, coll, cfg), want) in uni.iter().zip(&reference) {
        let d = cache.get_or_tune(cl, pl, *coll, cfg).unwrap();
        assert_identical(&d, want, "post-storm query");
    }
}
