//! Failure injection: randomly mutate correct schedules and assert that
//! the verification pipeline (symbolic executor, postcondition check,
//! model validator) catches the corruption — or, for the benign mutation
//! classes, stays correct. This is the mutation-coverage test for the
//! correctness oracles themselves.

use mcomm::collectives::{allreduce, broadcast, gather, TargetHeuristic};
use mcomm::model::{CostModel, Multicore};
use mcomm::sched::{symexec, Schedule, XferKind};
use mcomm::topology::{switched, Cluster, Placement};
use mcomm::util::Rng;

fn setup() -> (Cluster, Placement) {
    let cl = switched(3, 4, 2);
    let pl = Placement::block(&cl);
    (cl, pl)
}

/// Apply one random structural mutation; returns a description, or None
/// if the schedule had nothing to mutate at the chosen spot.
fn mutate(s: &mut Schedule, rng: &mut Rng) -> Option<&'static str> {
    if s.rounds.is_empty() {
        return None;
    }
    let ri = rng.gen_range(0..s.rounds.len());
    if s.rounds[ri].xfers.is_empty() {
        return None;
    }
    let xi = rng.gen_range(0..s.rounds[ri].xfers.len());
    match rng.gen_range(0..6) {
        0 => {
            // Drop a transfer entirely: some destination misses data.
            s.rounds[ri].xfers.remove(xi);
            Some("drop transfer")
        }
        1 => {
            // Redirect to the sender's own source (self-loop).
            let src = s.rounds[ri].xfers[xi].src;
            s.rounds[ri].xfers[xi].dsts = vec![src];
            Some("self loop")
        }
        2 => {
            // Retarget the source to a rank that may not hold the data.
            let x = &mut s.rounds[ri].xfers[xi];
            x.src = (x.src + 1) % s.num_ranks;
            Some("retarget source")
        }
        3 => {
            // Strip the payload.
            s.rounds[ri].xfers[xi].payload.items.clear();
            Some("empty payload")
        }
        4 => {
            // Duplicate the transfer within its round: an external twin
            // trips the one-message-per-rank cap; a local twin delivers
            // the same data twice (idempotent — still correct).
            let dup = s.rounds[ri].xfers[xi].clone();
            s.rounds[ri].xfers.push(dup);
            Some("duplicate transfer")
        }
        5 => {
            // Swap two adjacent rounds: any cross-round data dependency
            // breaks; genuinely independent rounds commute.
            if s.rounds.len() < 2 {
                return None;
            }
            let a = ri.min(s.rounds.len() - 2);
            s.rounds.swap(a, a + 1);
            Some("swap adjacent rounds")
        }
        _ => unreachable!(),
    }
}

/// A mutated schedule must be rejected by at least one stage of the
/// pipeline: shape check, symbolic run, postcondition, or model validity.
fn pipeline_catches(cl: &Cluster, pl: &Placement, s: &Schedule) -> bool {
    if s.check_shape(pl).is_err() {
        return true;
    }
    let st = match symexec::run(s) {
        Err(_) => return true,
        Ok(st) => st,
    };
    if symexec::check_final(s, &st).is_err() {
        return true;
    }
    Multicore::default().validate(cl, pl, s).is_err()
}

/// Mutation classes that can leave the schedule *correct*: dropping a
/// redundant transfer, retargeting a source to another rank that also
/// holds the data, duplicating a local transfer (idempotent delivery),
/// and swapping two genuinely independent rounds. Everything else must
/// be caught — self-loops and empty payloads unconditionally (the shape
/// check rejects both outright).
const BENIGN_CLASSES: [&str; 4] =
    ["drop transfer", "retarget source", "duplicate transfer", "swap adjacent rounds"];

#[test]
fn mutations_are_caught() {
    let (cl, pl) = setup();
    let originals: Vec<Schedule> = vec![
        broadcast::mc_aware(&cl, &pl, 0, TargetHeuristic::FirstFit),
        broadcast::binomial(&pl, 0),
        gather::mc_aware(&cl, &pl, 0),
        allreduce::ring(&pl),
        allreduce::hierarchical_mc(&cl, &pl),
    ];
    let mut rng = Rng::seed_from_u64(99);
    let mut caught = 0usize;
    let mut attempted = 0usize;
    // Per-class (attempted, caught) — the oracle catch-rate table.
    let mut by_kind: std::collections::HashMap<&'static str, (usize, usize)> =
        std::collections::HashMap::new();
    for (oi, original) in originals.iter().enumerate() {
        symexec::verify(original).unwrap();
        for trial in 0..60 {
            let mut m = original.clone();
            let Some(kind) = mutate(&mut m, &mut rng) else { continue };
            if m == *original {
                continue;
            }
            attempted += 1;
            let tally = by_kind.entry(kind).or_default();
            tally.0 += 1;
            if pipeline_catches(&cl, &pl, &m) {
                caught += 1;
                tally.1 += 1;
            } else {
                // Surviving the whole pipeline means the mutant is still
                // a *correct* schedule, which only the benign-capable
                // classes can produce. Any other survivor is a hole in
                // the oracle.
                assert!(
                    BENIGN_CLASSES.contains(&kind),
                    "schedule {oi} trial {trial}: undetected '{kind}' mutation"
                );
            }
        }
    }
    // The catch-rate table must be exhaustive: every class exercised,
    // the always-fatal classes caught without exception.
    for kind in [
        "drop transfer",
        "self loop",
        "retarget source",
        "empty payload",
        "duplicate transfer",
        "swap adjacent rounds",
    ] {
        let &(a, c) = by_kind.get(kind).unwrap_or(&(0, 0));
        println!("mutation class {kind:>20}: {c}/{a} caught");
        assert!(a >= 15, "class '{kind}' under-exercised: {a} attempts");
        if !BENIGN_CLASSES.contains(&kind) {
            assert_eq!(c, a, "'{kind}' mutants must never survive");
        }
    }
    // The pipeline must catch the overwhelming majority overall (local
    // duplicates are the one class that is usually benign).
    assert!(attempted > 150, "not enough mutation attempts: {attempted}");
    let rate = caught as f64 / attempted as f64;
    assert!(
        rate > 0.75,
        "only {caught}/{attempted} mutations caught ({rate:.2})"
    );
}

#[test]
fn executor_rejects_mutants_without_hanging() {
    use mcomm::exec::{self, ExecParams};
    let (cl, pl) = setup();
    let original = allreduce::hierarchical_mc(&cl, &pl);
    let mut rng = Rng::seed_from_u64(5);
    let mut rejected = 0;
    for _ in 0..20 {
        let mut m = original.clone();
        if mutate(&mut m, &mut rng).is_none() || m == original {
            continue;
        }
        let inputs = exec::initial_inputs(&m, |_r, _c| vec![1.0f32; 8]);
        let t = std::time::Instant::now();
        let res = exec::run(&cl, &pl, &m, inputs, &ExecParams::zero());
        // Tightened from 5 s: mutants are rejected at plan compile time
        // (shape + symbolic proof), before any worker thread exists, and
        // runtime failures propagate through the abort flag in
        // milliseconds rather than a 10-second recv timeout.
        assert!(
            t.elapsed() < std::time::Duration::from_secs(2),
            "executor must fail fast, took {:?}",
            t.elapsed()
        );
        if res.is_err() {
            rejected += 1;
        }
    }
    assert!(rejected > 5, "executor rejected only {rejected} mutants");
}

#[test]
fn validator_rejects_nic_oversubscription_everywhere() {
    // Systematically duplicate external transfers until the NIC cap
    // trips; the validator must catch every oversubscribed variant.
    let (cl, pl) = setup();
    let s = broadcast::mc_aware(&cl, &pl, 0, TargetHeuristic::FirstFit);
    let model = Multicore::default();
    model.validate(&cl, &pl, &s).unwrap();
    for ri in 0..s.rounds.len() {
        for xi in 0..s.rounds[ri].xfers.len() {
            if s.rounds[ri].xfers[xi].kind != XferKind::External {
                continue;
            }
            let mut m = s.clone();
            // Duplicate the send from the same src (proc cap) 3 times.
            let dup = m.rounds[ri].xfers[xi].clone();
            m.rounds[ri].xfers.push(dup.clone());
            m.rounds[ri].xfers.push(dup);
            assert!(
                model.validate(&cl, &pl, &m).is_err(),
                "round {ri} xfer {xi}: duplicated send not caught"
            );
        }
    }
}
