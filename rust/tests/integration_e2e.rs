//! Cross-layer integration: PJRT runtime + coordinator + executor +
//! Pallas `combine` artifact cross-checks. Tests skip gracefully when
//! `artifacts/` has not been built (`make artifacts`).

use mcomm::coordinator::{AllreduceAlgo, Trainer, TrainerCfg};
use mcomm::exec::ExecParams;
use mcomm::runtime::{lit_f32_2d, Runtime};

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        None
    }
}

/// The Rust executor's allreduce and the Pallas `combine` kernel artifact
/// must agree numerically on the same gradient stack: this pins L3's
/// summation semantics to L1's.
#[test]
fn exec_allreduce_matches_pallas_combine_artifact() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).unwrap();
    let combine = rt.load("combine").unwrap();
    let (k, p) = (rt.meta.workers, rt.meta.num_params);

    // Trainer with exactly `workers` ranks.
    let cfg = TrainerCfg {
        machines: 2,
        cores: k / 2,
        nics: 2,
        steps: 0,
        algo: AllreduceAlgo::HierarchicalMc,
        ..Default::default()
    };
    let trainer = Trainer::new(&dir, &cfg).unwrap();
    assert_eq!(trainer.workers(), k);

    // Deterministic pseudo-gradients.
    let mut rng = mcomm::util::Rng::seed_from_u64(3);
    let grads: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..p).map(|_| (rng.gen_f64() as f32 - 0.5) * 0.1).collect())
        .collect();

    // L3 path: threaded executor running the hierarchical-mc schedule.
    let via_exec = trainer.allreduce_grads(&grads, &ExecParams::zero()).unwrap();

    // L1 path: the Pallas combine kernel compiled via PJRT.
    let mut stack = Vec::with_capacity(k * p);
    for g in &grads {
        stack.extend_from_slice(g);
    }
    let out = combine.run(&[lit_f32_2d(&stack, k, p).unwrap()]).unwrap();
    let via_pallas = out[0].to_vec::<f32>().unwrap();

    let mut max_err = 0.0f32;
    for i in 0..p {
        max_err = max_err.max((via_exec[i] - via_pallas[i]).abs());
    }
    assert!(max_err < 1e-4, "exec vs pallas combine max err {max_err}");
}

/// Both allreduce algorithms produce bit-compatible training trajectories
/// (same batches, same math — the schedule is the only difference).
#[test]
fn ring_and_hierarchical_training_trajectories_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut finals = Vec::new();
    for algo in [AllreduceAlgo::Ring, AllreduceAlgo::HierarchicalMc] {
        let cfg = TrainerCfg {
            machines: 2,
            cores: 2,
            nics: 1,
            steps: 6,
            lr: 0.5,
            algo,
            exec_params: ExecParams::zero(),
            seed: 11,
            log_every: 0,
            ..Default::default()
        };
        let mut trainer = Trainer::new(&dir, &cfg).unwrap();
        let rep = trainer.run(&cfg).unwrap();
        finals.push(rep.losses);
    }
    for (a, b) in finals[0].iter().zip(&finals[1]) {
        assert!(
            (a - b).abs() < 2e-3,
            "trajectories diverged: {a} vs {b} (ring vs hier)"
        );
    }
}

/// Recursive-doubling also trains correctly (third algorithm, pow2 ranks).
#[test]
fn recursive_doubling_trains() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = TrainerCfg {
        machines: 2,
        cores: 2,
        nics: 2,
        steps: 4,
        lr: 0.5,
        algo: AllreduceAlgo::RecursiveDoubling,
        exec_params: ExecParams::zero(),
        seed: 11,
        log_every: 0,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&dir, &cfg).unwrap();
    let rep = trainer.run(&cfg).unwrap();
    assert_eq!(rep.losses.len(), 4);
    assert!(rep.losses.iter().all(|l| l.is_finite()));
}
