//! Three-way differential gate for the real-process backend: on
//! randomized switched topologies × registry candidates, the proc
//! backend's per-round delivered-chunk stream must equal the thread
//! backend's, and both must equal the lowered simulator's `XferRecord`
//! stream (via the schedule-derived stream both are checked against) —
//! with byte-exact payloads.
//!
//! The proc backend runs every rank as a real OS process: spawned from
//! the `mcomm` binary (`CARGO_BIN_EXE_mcomm`) with the hidden
//! `--proc-worker` entry point, sharing data through `/dev/shm` segments
//! and loopback TCP. Beyond the delivery gate, this suite pins:
//!
//! * virtual time is **bit-identical** across backends (the proc worker
//!   mirrors the engine's accounting action for action);
//! * suppression-mode deaths report identically (`dead_ranks`, zeroed
//!   timing, same deliveries, same survivor outputs);
//! * an abort-mode death — a child process that really calls
//!   `exit(2)` mid-collective — surfaces with the same error string and
//!   walks the same `supervised_execute` repair ladder to bit-identical
//!   survivor outputs.
//!
//! Every test skips (loudly) when the proc backend cannot run, i.e. no
//! writable `/dev/shm` on this host.

use std::path::PathBuf;
use std::time::Duration;

use mcomm::coordinator::{
    collect_reduced_grads_of, seed_grad_store, AllreduceAlgo, Communicator,
    FailurePolicy, RecoveryOutcome,
};
use mcomm::exec::{self, BufferStore, ExecDelivery, ExecParams};
use mcomm::sched::{Chunk, LoweredSchedule, Schedule, TopoCtx, XferKind};
use mcomm::sim::{simulate_lowered, SimArena, SimParams};
use mcomm::topology::{switched, Placement};
use mcomm::tune::{candidates_for, Collective};
use mcomm::util::Rng;

/// The mcomm binary (has the `--proc-worker` entry point); the test
/// harness binary itself does not, so it must never be the worker exe.
fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_mcomm"))
}

fn proc_ready() -> bool {
    let ok = mcomm::exec::proc::available();
    if !ok {
        eprintln!("skipping: proc backend unavailable (no writable /dev/shm)");
    }
    ok
}

fn pat(r: usize, c: Chunk) -> Vec<f32> {
    // Integer-valued f32s: every summation order is exact, so cross-
    // backend output comparison can demand bit equality.
    vec![(r * 131 + c.0 as usize * 17) as f32, r as f32]
}

/// The schedule-derived delivery stream (same oracle as the thread
/// backend's differential suite): every transfer's payload chunks, one
/// entry per destination, tagged with round and kind.
fn expected_deliveries(s: &Schedule) -> Vec<ExecDelivery> {
    let mut out = Vec::new();
    for (ri, round) in s.rounds.iter().enumerate() {
        for x in &round.xfers {
            for &d in &x.dsts {
                for (ch, _) in &x.payload.items {
                    out.push(ExecDelivery {
                        round: ri as u32,
                        src: x.src as u32,
                        dst: d as u32,
                        chunk: *ch,
                        external: x.kind == XferKind::External,
                    });
                }
            }
        }
    }
    out.sort_unstable();
    out
}

/// The schedule-derived record stream in the lowered simulator's
/// emission order.
fn expected_records(s: &Schedule) -> Vec<(usize, usize, bool, u64)> {
    let mut out = Vec::new();
    for round in &s.rounds {
        for x in &round.xfers {
            let bytes: u64 =
                x.payload.items.iter().map(|(c, _)| s.msg.chunk_bytes(c.0)).sum();
            match x.kind {
                XferKind::External | XferKind::LocalRead => {
                    out.push((x.src, x.dsts[0], x.kind == XferKind::External, bytes));
                }
                XferKind::LocalWrite => {
                    for &d in &x.dsts {
                        out.push((x.src, d, false, bytes));
                    }
                }
            }
        }
    }
    out
}

/// Byte-exact store equality: same chunk sets, same buffer counts, and
/// every thread-side buffer's contribution assembles on the proc side to
/// the same bits (payloads are integer-valued, so sums are exact).
fn assert_stores_match(a: &[BufferStore], b: &[BufferStore], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: rank count");
    for (r, (sa, sb)) in a.iter().zip(b).enumerate() {
        let mut ca: Vec<Chunk> = sa.chunks().collect();
        let mut cb: Vec<Chunk> = sb.chunks().collect();
        ca.sort_unstable();
        cb.sort_unstable();
        assert_eq!(ca, cb, "{what}: rank {r}: chunk sets");
        for c in ca {
            assert_eq!(
                sa.buffers(c).len(),
                sb.buffers(c).len(),
                "{what}: rank {r} {c:?}: buffer count"
            );
            for buf in sa.buffers(c) {
                let got = sb.assemble(c, &buf.contrib).unwrap_or_else(|e| {
                    panic!("{what}: rank {r} {c:?}: proc side lacks {}: {e}", buf.contrib)
                });
                assert_eq!(buf.data.len(), got.len(), "{what}: rank {r} {c:?}: len");
                for (i, (x, y)) in buf.data.iter().zip(got.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{what}: rank {r} {c:?} elem {i}: {x} vs {y}"
                    );
                }
            }
        }
    }
}

/// The gate itself: proc deliveries == thread deliveries == lowered-sim
/// record stream, byte-exact outputs, on randomized topologies across
/// registry candidates.
#[test]
fn three_way_differential_proc_thread_simulator() {
    if !proc_ready() {
        return;
    }
    let thread_params = ExecParams::zero().with_deliveries();
    let proc_params =
        ExecParams::zero().with_deliveries().with_proc_backend(Some(worker_exe()));
    let sim_params = SimParams::lan_cluster().with_records();
    let mut arena = SimArena::new();

    for seed in 0..2u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0x9B0C);
        // Small enough that a few dozen candidate runs (each spawning one
        // OS process per rank) stay inside a CI smoke budget.
        let cl = switched(
            2 + rng.gen_range(0..2),
            1 + rng.gen_range(0..2),
            1 + rng.gen_range(0..2),
        );
        let pl = Placement::block(&cl);
        let n = pl.num_ranks();
        if n < 2 {
            continue;
        }
        let root = rng.gen_range(0..n);
        let ctx = TopoCtx::new(&cl, &pl);
        let mut cases = 0usize;

        for coll in [
            Collective::Broadcast { root },
            Collective::Gather { root },
            Collective::Allreduce,
            Collective::ReduceScatter,
            Collective::AllToAll,
        ] {
            for cand in candidates_for(coll, &cl, &pl) {
                let s = cand
                    .build(&cl, &pl)
                    .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", cand.label()))
                    .with_total_bytes(1 + rng.gen_range(0..(1 << 16)) as u64);
                let ctx_s = format!("seed {seed} {}", cand.label());

                // Leg 1: lowered-simulator record stream == schedule stream.
                let low = LoweredSchedule::compile(&ctx, &s)
                    .unwrap_or_else(|e| panic!("{ctx_s}: lower: {e}"));
                let sim = simulate_lowered(&low, &sim_params, &mut arena);
                let want_records = expected_records(&s);
                assert_eq!(sim.records.len(), want_records.len(), "{ctx_s}: records");
                for (rec, want) in sim.records.iter().zip(&want_records) {
                    assert_eq!(
                        (rec.src, rec.dst, rec.external, rec.bytes),
                        (want.0, want.1, want.2, want.3),
                        "{ctx_s}"
                    );
                }

                // Legs 2+3: both backends == the same stream, and each
                // other, with byte-exact outputs.
                let want = expected_deliveries(&s);
                let rep_t = exec::run(&cl, &pl, &s, exec::initial_inputs(&s, pat), &thread_params)
                    .unwrap_or_else(|e| panic!("{ctx_s}: thread exec: {e}"));
                let rep_p = exec::run(&cl, &pl, &s, exec::initial_inputs(&s, pat), &proc_params)
                    .unwrap_or_else(|e| panic!("{ctx_s}: proc exec: {e}"));
                assert_eq!(rep_t.deliveries, want, "{ctx_s}: thread vs schedule");
                assert_eq!(rep_p.deliveries, want, "{ctx_s}: proc vs schedule");
                assert_stores_match(&rep_t.outputs, &rep_p.outputs, &ctx_s);
                cases += 1;
            }
        }
        assert!(cases >= 5, "seed {seed}: only {cases} candidates exercised");
    }
}

/// Virtual time must not depend on which backend ran the plan: the proc
/// worker replays the engine's vt accounting action for action, so the
/// makespans agree to the last bit (and across repeat proc runs).
#[test]
fn virtual_time_is_bit_identical_across_backends() {
    if !proc_ready() {
        return;
    }
    let cl = switched(3, 2, 2);
    let pl = Placement::block(&cl);
    let s = mcomm::collectives::allreduce::hierarchical_mc(&cl, &pl);
    let thread_params = ExecParams::lan_scaled().with_virtual_time();
    let proc_params =
        ExecParams::lan_scaled().with_virtual_time().with_proc_backend(Some(worker_exe()));

    let vt_thread = exec::run(&cl, &pl, &s, exec::initial_inputs(&s, pat), &thread_params)
        .unwrap()
        .virtual_time
        .expect("virtual mode");
    assert!(vt_thread > 0.0, "injected costs must show up");
    for trial in 0..2 {
        let vt_proc = exec::run(&cl, &pl, &s, exec::initial_inputs(&s, pat), &proc_params)
            .unwrap()
            .virtual_time
            .expect("virtual mode");
        assert_eq!(
            vt_thread.to_bits(),
            vt_proc.to_bits(),
            "trial {trial}: thread {vt_thread} vs proc {vt_proc}"
        );
    }
}

/// Suppression-mode parity: a rank marked dead (no abort) leaves the
/// same holes under both backends — same `dead_ranks`, zeroed timing
/// (the satellite-1 contract), same deliveries, same survivor outputs.
#[test]
fn suppressed_death_reports_identically_across_backends() {
    if !proc_ready() {
        return;
    }
    let cl = switched(3, 2, 1);
    let pl = Placement::block(&cl);
    let s = mcomm::collectives::allreduce::hierarchical_mc(&cl, &pl);
    let thread_params = ExecParams::zero().with_deliveries().with_dead_rank(4, 1);
    let proc_params = thread_params.clone().with_proc_backend(Some(worker_exe()));

    let rep_t =
        exec::run(&cl, &pl, &s, exec::initial_inputs(&s, pat), &thread_params).unwrap();
    let rep_p =
        exec::run(&cl, &pl, &s, exec::initial_inputs(&s, pat), &proc_params).unwrap();

    for (rep, which) in [(&rep_t, "thread"), (&rep_p, "proc")] {
        assert_eq!(rep.dead_ranks, vec![4], "{which}: dead ranks");
        assert_eq!(rep.wall, Duration::ZERO, "{which}: wall zeroed on death");
        assert_eq!(rep.virtual_time, None, "{which}: vt zeroed on death");
    }
    assert_eq!(rep_t.deliveries, rep_p.deliveries, "suppressed delivery streams");
    assert_stores_match(&rep_t.outputs, &rep_p.outputs, "suppressed outputs");
}

const P: usize = 16; // gradient elements for the recovery parity test

/// Integer-valued gradients: exact f32 sums, bit-comparable results.
fn grads(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|r| (0..P).map(|i| ((r + 2) * (i % 17 + 1)) as f32).collect())
        .collect()
}

/// Abort-mode parity end to end: the killed child *process* (a real
/// `exit(2)` mid-collective) must surface with the thread backend's
/// exact error string, classify structurally, and walk the same
/// repair ladder under `supervised_execute` to bit-identical survivor
/// outputs.
#[test]
fn killed_child_walks_recovery_ladder_like_thread_backend() {
    if !proc_ready() {
        return;
    }
    let n = 6;
    let g = grads(n);
    let mk_comm = || Communicator::block(switched(3, 2, 1));
    let s = {
        let comm = mk_comm();
        let mut s = comm.allreduce(AllreduceAlgo::Ring).unwrap();
        s.set_payload(4 * P as u64, 4);
        s
    };
    let seed = |sch: &Schedule, rank: usize, orig: usize| seed_grad_store(sch, rank, &g[orig]);
    // Rank 4 dies at round 1 — mid reduce-scatter; repair must succeed.
    let thread_params = ExecParams::zero().with_dead_rank(4, 1).with_abort_on_death();
    let proc_params = thread_params.clone().with_proc_backend(Some(worker_exe()));

    // Error-string parity on a bare execute.
    let mk_inputs = |s: &Schedule| (0..n).map(|r| seed(s, r, r)).collect::<Vec<_>>();
    let err_t = mk_comm().execute(&s, mk_inputs(&s), &thread_params).unwrap_err();
    let err_p = mk_comm().execute(&s, mk_inputs(&s), &proc_params).unwrap_err();
    assert_eq!(err_t.to_string(), err_p.to_string(), "abort error strings");
    assert!(err_t.to_string().contains("rank 4 died at round 1"), "{err_t}");

    // Supervised ladder parity: same structural classification, same
    // Repaired outcome, bit-identical survivor outputs.
    let mut tc = mk_comm();
    let sup_t = tc
        .supervised_execute(&s, &seed, &thread_params, &FailurePolicy::default())
        .unwrap();
    let mut pc = mk_comm();
    let sup_p = pc
        .supervised_execute(&s, &seed, &proc_params, &FailurePolicy::default())
        .unwrap();

    match &sup_p.outcome {
        RecoveryOutcome::Repaired { dead_ranks, cut, patch_rounds, .. } => {
            assert_eq!(dead_ranks, &vec![4]);
            assert_eq!(*cut, 1);
            assert!(*patch_rounds > 0, "patch must add rounds");
        }
        o => panic!("expected Repaired, got {o:?}"),
    }
    assert_eq!(sup_t.outcome, sup_p.outcome, "recovery outcomes");
    assert_eq!(sup_p.attempts, 1, "one pass, not a retry per corpse");
    assert_eq!(sup_p.report.dead_ranks, vec![4]);

    let survivors = [0usize, 1, 2, 3, 5];
    for &r in &survivors {
        let a = collect_reduced_grads_of(&s, &sup_t.report.outputs[r], &survivors, P)
            .unwrap();
        let b = collect_reduced_grads_of(&s, &sup_p.report.outputs[r], &survivors, P)
            .unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "survivor {r} elem {i}: thread {x} vs proc {y}"
            );
        }
    }
}
