//! Property tests for the calibration subsystem — the acceptance gates
//! of the exec → model → tune loop:
//!
//! * **Round-trip**: `MachineProfile` JSON serialization is bit-exact,
//!   including digests, across randomized profiles.
//! * **Determinism**: the same probe data fits to a bit-identical
//!   profile (virtual-time probes are themselves deterministic, so two
//!   full calibrations agree bitwise).
//! * **Recovery**: on synthetic virtual-time probes with injected
//!   physics, every fitted parameter lands within 5% of the injected
//!   value (in practice: float precision) across randomized topologies
//!   *and* randomized injected parameters.
//! * **Decisions move**: `tune::select` under a profile calibrated on a
//!   skewed machine (slow NIC or slow shared memory) disagrees with the
//!   default-constants configuration on at least one collective.

use std::time::Duration;

use mcomm::calibrate::{run_calibration, CalibrateCfg, MachineProfile, PARAM_NAMES};
use mcomm::coordinator::Communicator;
use mcomm::exec::ExecParams;
use mcomm::topology::{switched, Placement};
use mcomm::tune::{select, Collective, TuneCfg};
use mcomm::util::Rng;

fn random_profile(rng: &mut Rng) -> MachineProfile {
    // Drive the fields through a real calibration? No — this exercises
    // the codec against arbitrary magnitudes, including awkward
    // non-terminating decimals.
    MachineProfile {
        version: mcomm::calibrate::PROFILE_VERSION,
        o_send: rng.gen_f64() * 1e-4,
        o_recv: rng.gen_f64() * 1e-4,
        o_write: rng.gen_f64() * 1e-5,
        lat_ext: rng.gen_f64() * 1e-2,
        byte_ext: rng.gen_f64() / 3e9,
        byte_int: rng.gen_f64() / 7e9,
        round_overhead: rng.gen_f64() * 1e-6,
        nic_contention: 1.0 + rng.gen_f64(),
        residual: rng.gen_f64() * 1e-12,
        mode: if rng.gen_bool(0.5) { "virtual".into() } else { "wall".into() },
        repeats: 1 + rng.gen_range(0..9),
        probe_rounds: 1 + rng.gen_range(0..8),
        machines: 1 + rng.gen_range(0..16),
        ranks: 1 + rng.gen_range(0..128),
    }
}

#[test]
fn profile_json_round_trip_is_bit_exact_randomized() {
    let mut rng = Rng::seed_from_u64(0xCA11B);
    for i in 0..200 {
        let p = random_profile(&mut rng);
        let back = MachineProfile::from_json(&p.to_json())
            .unwrap_or_else(|e| panic!("iteration {i}: {e}\n{}", p.to_json()));
        assert_eq!(p, back, "iteration {i}");
        assert_eq!(p.digest(), back.digest(), "iteration {i}");
        for (a, b) in p.theta().iter().zip(back.theta().iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "iteration {i}");
        }
    }
}

fn random_exec(rng: &mut Rng) -> ExecParams {
    // Whole-nanosecond draws: Duration stores nanoseconds, so these are
    // exactly the values the engine will account with.
    fn micros(rng: &mut Rng, lo: u64, hi: u64) -> Duration {
        Duration::from_nanos(1000 * (lo + rng.gen_range(0..(hi - lo) as usize) as u64))
    }
    ExecParams {
        o_send: micros(rng, 1, 30),
        o_recv: micros(rng, 1, 30),
        o_write: micros(rng, 1, 10),
        ext_latency: micros(rng, 10, 200),
        ext_byte_time: Duration::from_nanos(1 + rng.gen_range(0..40) as u64),
        int_byte_time: Duration::from_nanos(rng.gen_range(0..4) as u64),
        ..ExecParams::zero()
    }
}

/// The headline acceptance property: inject known virtual-time physics,
/// calibrate, recover every parameter within 5% — across randomized
/// topologies and randomized injected parameters.
#[test]
fn fitter_recovers_injected_physics_within_five_percent() {
    let mut rng = Rng::seed_from_u64(0xF17);
    for seed in 0..8 {
        let machines = 2 + rng.gen_range(0..3);
        let cores = 2 + rng.gen_range(0..3);
        let nics = 1 + rng.gen_range(0..2);
        let exec = random_exec(&mut rng);
        let cfg = CalibrateCfg::virtual_with(exec.clone());
        let comm = Communicator::block(switched(machines, cores, nics));
        let profile = run_calibration(&comm, &cfg)
            .unwrap_or_else(|e| panic!("seed {seed} ({machines}x{cores}): {e}"));

        let truth = [
            exec.o_send.as_secs_f64(),
            exec.o_recv.as_secs_f64(),
            exec.o_write.as_secs_f64(),
            exec.ext_latency.as_secs_f64(),
            exec.ext_byte_time.as_secs_f64(),
            exec.int_byte_time.as_secs_f64(),
            0.0,
        ];
        for ((name, want), got) in PARAM_NAMES.iter().zip(truth).zip(profile.theta()) {
            let err = (got - want).abs() / want.abs().max(1e-9);
            assert!(
                err < 0.05,
                "seed {seed} {name}: fitted {got} vs injected {want} (err {err:.2e})"
            );
        }
        // Virtual clocks are contention-free by construction.
        assert!(
            (profile.nic_contention - 1.0).abs() < 1e-9,
            "seed {seed}: contention {}",
            profile.nic_contention
        );
        assert!(profile.residual < 1e-6, "seed {seed}: residual {}", profile.residual);
    }
}

/// Same probe data ⇒ bit-identical profile. Virtual-time measurements
/// are deterministic, so two independent end-to-end calibrations (fresh
/// communicator, fresh engine, fresh fit) must agree bitwise — this
/// pins both the runner and the fitter.
#[test]
fn calibration_is_bit_deterministic() {
    for (m, c, k) in [(2usize, 2usize, 1usize), (3, 4, 2)] {
        let cfg = CalibrateCfg::default();
        let a = run_calibration(&Communicator::block(switched(m, c, k)), &cfg).unwrap();
        let b = run_calibration(&Communicator::block(switched(m, c, k)), &cfg).unwrap();
        assert_eq!(a, b, "{m}x{c}x{k}");
        assert_eq!(a.digest(), b.digest());
        for (x, y) in a.theta().iter().zip(b.theta().iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{m}x{c}x{k}");
        }
        // And the serialized artifact is byte-identical.
        assert_eq!(a.to_json(), b.to_json());
    }
}

/// Calibrating a skewed machine must actually *move* tuning decisions:
/// select with the calibrated TuneCfg disagrees with the
/// default-constants TuneCfg somewhere. Two opposite skews are swept
/// (slow NIC / slow shared memory) over several topologies and every
/// collective; at least one decision must change.
#[test]
fn calibrated_profile_changes_tuning_decisions_on_skewed_machines() {
    // Slow NIC: millisecond latency, ~1 MB/s wire, free shared memory.
    let slow_nic = ExecParams {
        ext_latency: Duration::from_millis(10),
        o_send: Duration::from_millis(1),
        o_recv: Duration::from_millis(1),
        ext_byte_time: Duration::from_micros(1),
        o_write: Duration::from_nanos(10),
        int_byte_time: Duration::from_nanos(0),
        ..ExecParams::zero()
    };
    // Slow shared memory: reads/writes cost milliseconds against a fast,
    // low-latency NIC.
    let slow_shm = ExecParams {
        ext_latency: Duration::from_micros(1),
        o_send: Duration::from_micros(1),
        o_recv: Duration::from_micros(1),
        ext_byte_time: Duration::from_nanos(1),
        o_write: Duration::from_millis(5),
        int_byte_time: Duration::from_micros(1),
        ..ExecParams::zero()
    };

    let probe_topo = Communicator::block(switched(2, 2, 1));
    let default_cfg = TuneCfg::default();
    let root = 0;
    let colls = [
        Collective::Broadcast { root },
        Collective::Gather { root },
        Collective::Scatter { root },
        Collective::Reduce { root },
        Collective::Allgather,
        Collective::AllToAll,
        Collective::Allreduce,
        Collective::ReduceScatter,
    ];
    let topologies = [switched(4, 4, 2), switched(2, 8, 1), switched(8, 2, 2)];

    let mut changed = 0usize;
    let mut total = 0usize;
    for exec in [slow_nic, slow_shm] {
        let profile =
            run_calibration(&probe_topo, &CalibrateCfg::virtual_with(exec)).unwrap();
        let calibrated_cfg = TuneCfg::from_profile(&profile, 16 << 10);
        assert_eq!(calibrated_cfg.profile_digest, profile.digest());
        for cl in &topologies {
            let pl = Placement::block(cl);
            for coll in colls {
                let d_def = select(cl, &pl, coll, &default_cfg).unwrap();
                let d_cal = select(cl, &pl, coll, &calibrated_cfg).unwrap();
                total += 1;
                if d_def.choice != d_cal.choice {
                    changed += 1;
                }
            }
        }
    }
    assert!(
        changed >= 1,
        "calibrated physics changed no decision across {total} (collective, \
         topology, skew) combinations — the profile is not reaching the tuner"
    );
}
