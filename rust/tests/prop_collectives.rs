//! Property tests over randomized topologies: every schedule builder, on
//! every random cluster shape, must (a) verify symbolically, (b) be
//! legal — directly or after legalization — under the multi-core model,
//! (c) simulate without error, and (d) for a sample of cases, move real
//! bytes correctly through the threaded executor.
//!
//! The offline build has no proptest crate; this is a seeded-sweep
//! equivalent (deterministic, ~200 distinct cases per run) with shrink-
//! free but fully reproducible failures (the failing seed is in the
//! panic message).

use mcomm::collectives::{
    allgather, allreduce, alltoall, broadcast, gather, reduce, scatter, TargetHeuristic,
};
use mcomm::exec::{self, ExecParams};
use mcomm::model::{legalize, CostModel, Duplex, Multicore};
use mcomm::sched::{symexec, Schedule};
use mcomm::sim::{simulate, SimParams};
use mcomm::topology::{clustered, gnp, switched, Cluster, Placement};
use mcomm::util::Rng;

/// Random cluster from a seed: switch or connected graph, 2..6 machines,
/// 1..6 cores, 1..4 NICs.
fn random_cluster(seed: u64) -> Cluster {
    let mut rng = Rng::seed_from_u64(seed);
    let machines = 2 + rng.gen_range(0..5);
    let cores = 1 + rng.gen_range(0..6);
    let nics = 1 + rng.gen_range(0..4);
    match rng.gen_range(0..3) {
        0 => switched(machines, cores, nics),
        1 => gnp(machines.max(2), 0.5, cores, nics, seed ^ 0xABCD),
        _ => clustered(2, 2 + rng.gen_range(0..3), 0.8, cores, nics, seed ^ 0x1234),
    }
}

fn check_schedule(cl: &Cluster, pl: &Placement, s: &Schedule, ctx: &str) {
    symexec::verify(s).unwrap_or_else(|e| panic!("{ctx}: symexec: {e}"));
    let model = Multicore::default();
    let legal = legalize(&model, cl, pl, s);
    model
        .validate(cl, pl, &legal)
        .unwrap_or_else(|e| panic!("{ctx}: validate: {e}"));
    symexec::verify(&legal).unwrap_or_else(|e| panic!("{ctx}: legalized symexec: {e}"));
    simulate(cl, pl, &legal, &SimParams::lan_cluster())
        .unwrap_or_else(|e| panic!("{ctx}: simulate: {e}"));
}

#[test]
fn all_builders_verify_on_random_topologies() {
    for seed in 0..40u64 {
        let cl = random_cluster(seed);
        let pl = Placement::block(&cl);
        let n = pl.num_ranks();
        let mut rng = Rng::seed_from_u64(seed ^ 0xF00D);
        let root = rng.gen_range(0..n);
        let slots = (1 + rng.gen_range(0..2))
            .min(cl.degree(0))
            .min(pl.ranks_on(0).len())
            .max(1);
        let is_switch = matches!(
            cl.interconnect,
            mcomm::topology::Interconnect::FullSwitch
        );
        let ctx = |name: &str| format!("seed {seed} ({name}, root {root})");

        // Topology-aware builders work on any connected interconnect.
        check_schedule(
            &cl,
            &pl,
            &broadcast::hierarchical(&cl, &pl, root),
            &ctx("hierarchical"),
        );
        for h in [
            TargetHeuristic::FirstFit,
            TargetHeuristic::FastestNodeFirst,
            TargetHeuristic::HighestDegreeFirst,
            TargetHeuristic::CoverageAware,
        ] {
            check_schedule(
                &cl,
                &pl,
                &broadcast::mc_aware(&cl, &pl, root, h),
                &ctx(h.name()),
            );
        }
        check_schedule(&cl, &pl, &gather::mc_aware(&cl, &pl, root), &ctx("mc_gather"));
        check_schedule(&cl, &pl, &scatter::mc_aware(&cl, &pl, root), &ctx("mc_scatter"));
        check_schedule(&cl, &pl, &reduce::mc_aware(&cl, &pl, root), &ctx("reduce_mc"));

        // Flat algorithms assume any-to-any reachability (the LogP
        // premise); they only apply on switched interconnects.
        if is_switch {
            check_schedule(&cl, &pl, &broadcast::flat_tree(&pl, root), &ctx("flat_tree"));
            check_schedule(&cl, &pl, &broadcast::binomial(&pl, root), &ctx("binomial"));
            check_schedule(
                &cl,
                &pl,
                &gather::flat_gather(&pl, root),
                &ctx("flat_gather"),
            );
            check_schedule(
                &cl,
                &pl,
                &gather::inverse_binomial(&pl, root),
                &ctx("inverse_binomial"),
            );
            check_schedule(
                &cl,
                &pl,
                &scatter::flat_scatter(&pl, root),
                &ctx("flat_scatter"),
            );
            check_schedule(&cl, &pl, &scatter::binomial(&pl, root), &ctx("bin_scatter"));
            check_schedule(&cl, &pl, &alltoall::pairwise(&pl), &ctx("pairwise"));
            check_schedule(&cl, &pl, &alltoall::bruck(&pl), &ctx("bruck"));
            check_schedule(
                &cl,
                &pl,
                &alltoall::leader_aggregated(&cl, &pl, slots),
                &ctx("leader_aggregated"),
            );
            check_schedule(&cl, &pl, &allgather::ring(&pl), &ctx("ag_ring"));
            check_schedule(
                &cl,
                &pl,
                &allgather::mc_aware(&cl, &pl, slots),
                &ctx("ag_mc"),
            );
            check_schedule(&cl, &pl, &reduce::binomial(&pl, root), &ctx("reduce_bin"));
            if n > 1 {
                check_schedule(&cl, &pl, &allreduce::ring(&pl), &ctx("ar_ring"));
            }
            check_schedule(
                &cl,
                &pl,
                &allreduce::hierarchical_mc(&cl, &pl),
                &ctx("ar_hier"),
            );
            if n.is_power_of_two() && n > 1 {
                check_schedule(
                    &cl,
                    &pl,
                    &allreduce::recursive_doubling(&pl).unwrap(),
                    &ctx("ar_recdoub"),
                );
                check_schedule(
                    &cl,
                    &pl,
                    &allreduce::rabenseifner(&pl).unwrap(),
                    &ctx("ar_raben"),
                );
            }
        }
    }
}

/// Segmentation sweep: `segmented(S)` of a builder's output must (a)
/// verify symbolically (per-segment initial/final state), (b) stay — or
/// legalize — model-legal, (c) simulate, and (d) preserve the total
/// payload while multiplying the chunk space by S.
#[test]
fn segmented_builders_verify_on_random_topologies() {
    use mcomm::collectives::segmented::segmented;
    for seed in 0..20u64 {
        let cl = random_cluster(seed);
        let pl = Placement::block(&cl);
        let n = pl.num_ranks();
        let mut rng = Rng::seed_from_u64(seed ^ 0x5E6);
        let root = rng.gen_range(0..n);
        let segments = [2u32, 3, 4][rng.gen_range(0..3)];
        let bytes = 1 + rng.gen_range(0..(1 << 20)) as u64;
        let is_switch = matches!(
            cl.interconnect,
            mcomm::topology::Interconnect::FullSwitch
        );

        let mut inners = vec![
            broadcast::mc_aware(&cl, &pl, root, TargetHeuristic::CoverageAware),
            gather::mc_aware(&cl, &pl, root),
            scatter::mc_aware(&cl, &pl, root),
            reduce::mc_aware(&cl, &pl, root),
        ];
        if is_switch {
            inners.push(broadcast::chain_mc(&cl, &pl, root));
            inners.push(broadcast::binomial(&pl, root));
            inners.push(allgather::ring(&pl));
            if n > 1 {
                inners.push(allreduce::ring(&pl));
            }
        }
        for inner in inners {
            let inner = inner.with_total_bytes(bytes);
            let ctx = format!("seed {seed} seg{segments} ({})", inner.algo);
            let piped = segmented(&cl, &pl, &inner, segments)
                .unwrap_or_else(|e| panic!("{ctx}: segmented: {e}"));
            assert_eq!(piped.msg.total_bytes, inner.msg.total_bytes, "{ctx}");
            assert_eq!(piped.msg.segments, segments, "{ctx}");
            assert_eq!(
                piped.external_messages(),
                segments as usize * inner.external_messages(),
                "{ctx}"
            );
            check_schedule(&cl, &pl, &piped, &ctx);
        }
    }
}

/// Half-duplex sweep: every builder output — constructed assuming full
/// duplex — must legalize to a schedule that satisfies the stricter
/// `sends + receives <= k` cap, still verify symbolically, and still
/// simulate. This is the `Duplex::Half` counterpart of the sweep above.
#[test]
fn half_duplex_legalization_on_random_topologies() {
    let model = Multicore { duplex: Duplex::Half, ..Multicore::default() };
    let check = |cl: &Cluster, pl: &Placement, s: &Schedule, ctx: &str| {
        symexec::verify(s).unwrap_or_else(|e| panic!("{ctx}: symexec: {e}"));
        let legal = legalize(&model, cl, pl, s);
        model
            .validate(cl, pl, &legal)
            .unwrap_or_else(|e| panic!("{ctx}: half-duplex validate: {e}"));
        symexec::verify(&legal)
            .unwrap_or_else(|e| panic!("{ctx}: legalized symexec: {e}"));
        simulate(cl, pl, &legal, &SimParams::lan_cluster())
            .unwrap_or_else(|e| panic!("{ctx}: simulate: {e}"));
    };
    for seed in 0..25u64 {
        let cl = random_cluster(seed);
        let pl = Placement::block(&cl);
        let n = pl.num_ranks();
        let mut rng = Rng::seed_from_u64(seed ^ 0xBEEF);
        let root = rng.gen_range(0..n);
        let is_switch = matches!(
            cl.interconnect,
            mcomm::topology::Interconnect::FullSwitch
        );
        let ctx = |name: &str| format!("half-duplex seed {seed} ({name}, root {root})");

        check(
            &cl,
            &pl,
            &broadcast::mc_aware(&cl, &pl, root, TargetHeuristic::CoverageAware),
            &ctx("mc_bcast"),
        );
        check(&cl, &pl, &broadcast::hierarchical(&cl, &pl, root), &ctx("hier"));
        check(&cl, &pl, &gather::mc_aware(&cl, &pl, root), &ctx("mc_gather"));
        check(&cl, &pl, &scatter::mc_aware(&cl, &pl, root), &ctx("mc_scatter"));
        check(&cl, &pl, &reduce::mc_aware(&cl, &pl, root), &ctx("mc_reduce"));
        if is_switch {
            check(&cl, &pl, &broadcast::binomial(&pl, root), &ctx("binomial"));
            check(&cl, &pl, &alltoall::pairwise(&pl), &ctx("pairwise"));
            check(&cl, &pl, &allgather::ring(&pl), &ctx("ag_ring"));
            if n > 1 {
                check(&cl, &pl, &allreduce::ring(&pl), &ctx("ar_ring"));
            }
            check(
                &cl,
                &pl,
                &allreduce::hierarchical_mc(&cl, &pl),
                &ctx("ar_hier"),
            );
        }
    }
}

/// Real-byte spot checks: a random sample of (seed, op) pairs through the
/// executor with numeric verification.
#[test]
fn executor_matches_reference_on_random_cases() {
    let pat = |r: usize, c: mcomm::sched::Chunk| -> Vec<f32> {
        (0..3)
            .map(|i| (r * 31 + c.0 as usize * 7 + i) as f32 * 0.25)
            .collect()
    };
    for seed in 0..12u64 {
        // Switched shapes: hierarchical-mc's inter-machine rings need
        // any-to-any reachability.
        let mut shape_rng = Rng::seed_from_u64(seed + 1000);
        let cl = switched(
            2 + shape_rng.gen_range(0..4),
            1 + shape_rng.gen_range(0..5),
            1 + shape_rng.gen_range(0..3),
        );
        let pl = Placement::block(&cl);
        let n = pl.num_ranks();
        if n < 2 {
            continue;
        }
        let mut rng = Rng::seed_from_u64(seed);
        let root = rng.gen_range(0..n);

        // Broadcast: everyone ends with root's data.
        let s = broadcast::mc_aware(&cl, &pl, root, TargetHeuristic::CoverageAware);
        let rep = exec::run(&cl, &pl, &s, exec::initial_inputs(&s, pat), &ExecParams::zero())
            .unwrap_or_else(|e| panic!("seed {seed} bcast: {e}"));
        let want = pat(root, mcomm::sched::Chunk(0));
        for r in 0..n {
            assert_eq!(
                *rep.outputs[r].value(mcomm::sched::Chunk(0)).unwrap(),
                want,
                "seed {seed} rank {r}"
            );
        }

        // Allreduce: everyone ends with the sum.
        let s = allreduce::hierarchical_mc(&cl, &pl);
        let chunks = match s.op {
            mcomm::sched::CollectiveOp::Allreduce { chunks } => chunks,
            _ => unreachable!(),
        };
        let rep = exec::run(&cl, &pl, &s, exec::initial_inputs(&s, pat), &ExecParams::zero())
            .unwrap_or_else(|e| panic!("seed {seed} allreduce: {e}"));
        for c in 0..chunks {
            let ch = mcomm::sched::Chunk(c);
            let want: Vec<f32> = (0..3)
                .map(|i| (0..n).map(|r| pat(r, ch)[i]).sum())
                .collect();
            for r in 0..n {
                let got = rep.outputs[r]
                    .reduced_value(ch, n)
                    .unwrap_or_else(|| panic!("seed {seed} rank {r} chunk {c}"));
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g - w).abs() < 1e-3,
                        "seed {seed} rank {r} chunk {c}: {g} vs {w}"
                    );
                }
            }
        }
    }
}

/// Random placements (not just block): builders must stay correct when
/// ranks are scattered round-robin across machines.
#[test]
fn round_robin_placement_still_verifies() {
    for seed in 0..15u64 {
        let mut shape_rng = Rng::seed_from_u64(seed + 2000);
        let cl = switched(
            2 + shape_rng.gen_range(0..4),
            1 + shape_rng.gen_range(0..5),
            1 + shape_rng.gen_range(0..3),
        );
        let pl = Placement::round_robin(&cl);
        let n = pl.num_ranks();
        let mut rng = Rng::seed_from_u64(seed);
        let root = rng.gen_range(0..n);
        check_schedule(
            &cl,
            &pl,
            &broadcast::binomial(&pl, root),
            &format!("rr binomial seed {seed}"),
        );
        check_schedule(
            &cl,
            &pl,
            &broadcast::mc_aware(&cl, &pl, root, TargetHeuristic::FirstFit),
            &format!("rr mc seed {seed}"),
        );
        check_schedule(
            &cl,
            &pl,
            &gather::mc_aware(&cl, &pl, root),
            &format!("rr gather seed {seed}"),
        );
        check_schedule(
            &cl,
            &pl,
            &allreduce::ring(&pl),
            &format!("rr ring seed {seed}"),
        );
    }
}
