//! Differential suite for the persistent executor: the engine's
//! per-round delivered-chunk stream must agree with the lowered
//! simulator's `XferRecord` stream on randomized switched topologies
//! across every registry candidate.
//!
//! Both engines consume the same `Schedule`, so the schedule's own
//! round/transfer structure is the meeting point: (1) the lowered
//! simulator's record stream is checked against the schedule-derived
//! stream record-for-record (it emits records in round-major transfer
//! order — one per external/read, one per `LocalWrite` destination);
//! (2) the executor's delivery records, gathered from the worker
//! threads, must equal the same schedule-derived stream chunk-for-chunk
//! (round, src, dst, chunk, external). Together: engine deliveries ==
//! simulator records, with the chunk-level detail the `XferRecord`
//! doesn't carry made explicit.
//!
//! One `ExecEngine` serves every candidate of a topology (same rank
//! count), so this suite also hammers pool reuse across dozens of
//! different plans back-to-back.

use std::sync::Arc;

use mcomm::exec::{self, ExecDelivery, ExecEngine, ExecParams, ExecPlan};
use mcomm::sched::{Chunk, LoweredSchedule, Schedule, TopoCtx, XferKind};
use mcomm::sim::{simulate_lowered, SimArena, SimParams};
use mcomm::topology::{switched, Placement};
use mcomm::tune::{candidates_for, Collective};
use mcomm::util::Rng;

fn pat(r: usize, c: Chunk) -> Vec<f32> {
    vec![(r * 131 + c.0 as usize * 17) as f32, r as f32]
}

/// The schedule-derived delivery stream: every transfer's payload chunks,
/// one entry per destination, tagged with round and kind.
fn expected_deliveries(s: &Schedule) -> Vec<ExecDelivery> {
    let mut out = Vec::new();
    for (ri, round) in s.rounds.iter().enumerate() {
        for x in &round.xfers {
            for &d in &x.dsts {
                for (ch, _) in &x.payload.items {
                    out.push(ExecDelivery {
                        round: ri as u32,
                        src: x.src as u32,
                        dst: d as u32,
                        chunk: *ch,
                        external: x.kind == XferKind::External,
                    });
                }
            }
        }
    }
    out.sort_unstable();
    out
}

/// The schedule-derived record stream in the lowered simulator's
/// emission order: (src, dst, external, serialized bytes per the
/// schedule's MsgSpec) per record.
fn expected_records(s: &Schedule) -> Vec<(usize, usize, bool, u64)> {
    let mut out = Vec::new();
    for round in &s.rounds {
        for x in &round.xfers {
            let bytes: u64 =
                x.payload.items.iter().map(|(c, _)| s.msg.chunk_bytes(c.0)).sum();
            match x.kind {
                XferKind::External | XferKind::LocalRead => {
                    out.push((x.src, x.dsts[0], x.kind == XferKind::External, bytes));
                }
                XferKind::LocalWrite => {
                    for &d in &x.dsts {
                        out.push((x.src, d, false, bytes));
                    }
                }
            }
        }
    }
    out
}

#[test]
fn engine_deliveries_match_lowered_simulator_records() {
    let exec_params = ExecParams::zero().with_deliveries();
    let sim_params = SimParams::lan_cluster().with_records();
    let mut arena = SimArena::new();

    for seed in 0..6u64 {
        let mut rng = Rng::seed_from_u64(seed ^ 0xD1FF);
        let cl = switched(
            2 + rng.gen_range(0..3),
            1 + rng.gen_range(0..3),
            1 + rng.gen_range(0..2),
        );
        let pl = Placement::block(&cl);
        let n = pl.num_ranks();
        if n < 2 {
            continue;
        }
        let root = rng.gen_range(0..n);
        let ctx = TopoCtx::new(&cl, &pl);
        // One pool for every candidate on this topology.
        let mut engine = ExecEngine::new(n);
        let mut cases = 0usize;

        for coll in [
            Collective::Broadcast { root },
            Collective::Gather { root },
            Collective::Scatter { root },
            Collective::Reduce { root },
            Collective::Allgather,
            Collective::AllToAll,
            Collective::Allreduce,
            Collective::ReduceScatter,
        ] {
            for cand in candidates_for(coll, &cl, &pl) {
                // Randomized total size: record bytes must follow the
                // schedule's MsgSpec (uneven chunk tails included).
                let s = cand
                    .build(&cl, &pl)
                    .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", cand.label()))
                    .with_total_bytes(1 + rng.gen_range(0..(1 << 16)) as u64);
                let ctx_s = format!("seed {seed} {}", cand.label());

                // Lowered simulator record stream == schedule stream.
                let low = LoweredSchedule::compile(&ctx, &s)
                    .unwrap_or_else(|e| panic!("{ctx_s}: lower: {e}"));
                let sim = simulate_lowered(&low, &sim_params, &mut arena);
                let want_records = expected_records(&s);
                assert_eq!(sim.records.len(), want_records.len(), "{ctx_s}: record count");
                for (rec, want) in sim.records.iter().zip(&want_records) {
                    assert_eq!(
                        (rec.src, rec.dst, rec.external),
                        (want.0, want.1, want.2),
                        "{ctx_s}"
                    );
                    assert_eq!(rec.bytes, want.3, "{ctx_s}: bytes");
                }

                // Engine per-round deliveries == the same stream, with
                // per-chunk detail.
                let plan = Arc::new(
                    ExecPlan::compile(&pl, &s)
                        .unwrap_or_else(|e| panic!("{ctx_s}: plan: {e}")),
                );
                let rep = engine
                    .execute(&plan, exec::initial_inputs(&s, pat), &exec_params)
                    .unwrap_or_else(|e| panic!("{ctx_s}: exec: {e}"));
                assert_eq!(rep.deliveries, expected_deliveries(&s), "{ctx_s}");
                cases += 1;
            }
        }
        assert!(cases >= 10, "seed {seed}: only {cases} candidates exercised");
        assert_eq!(engine.runs(), cases, "pool must have served every candidate");
    }
}

#[test]
fn virtual_time_is_deterministic_across_pools() {
    // The same plan under the same virtual-time params must produce a
    // bit-identical makespan from two different engines (nothing about
    // thread scheduling may leak into the clock).
    let cl = switched(3, 2, 2);
    let pl = Placement::block(&cl);
    let s = mcomm::collectives::allreduce::hierarchical_mc(&cl, &pl);
    let plan = Arc::new(ExecPlan::compile(&pl, &s).unwrap());
    let params = ExecParams::lan_scaled().with_virtual_time();

    let mut vts = Vec::new();
    for _ in 0..2 {
        let mut engine = ExecEngine::new(pl.num_ranks());
        for _ in 0..3 {
            let rep = engine
                .execute(&plan, exec::initial_inputs(&s, pat), &params)
                .unwrap();
            vts.push(rep.virtual_time.expect("virtual mode").to_bits());
        }
    }
    assert!(vts.iter().all(|&v| v == vts[0]), "virtual times diverged: {vts:?}");
    assert!(f64::from_bits(vts[0]) > 0.0, "injected costs must show up");
}
