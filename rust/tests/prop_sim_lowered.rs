//! Differential property suite: the production simulator
//! (`sched::lowered` compile + `sim::simulate_lowered` over arena
//! scratch) must reproduce the golden reference engine
//! (`sim::simulate_reference`) **exactly** — bit-identical `t_end` and
//! identical `ext_messages`, `ext_bytes`, `nic_utilization` and
//! per-transfer records — across randomized topologies (switched and
//! graph), every collective's full candidate set, both duplex
//! legalizations, and all simulator parameter presets — straggler
//! slowdowns and mid-schedule rank deaths included.
//!
//! One shared `SimArena` is threaded through every lowered run, so the
//! suite also proves arena reset/reuse leaks no state between schedules
//! or topologies.

use mcomm::model::{legalize, Duplex, Multicore};
use mcomm::sched::{LoweredSchedule, Schedule, TopoCtx};
use mcomm::sim::{simulate_lowered, simulate_reference, SimArena, SimParams};
use mcomm::topology::{gnp, switched, Cluster, Placement};
use mcomm::tune::{candidates_for, Collective};
use mcomm::util::Rng;

fn param_grid() -> Vec<SimParams> {
    let mut speedy = SimParams::lan_cluster().with_records();
    speedy.respect_speed = true;
    vec![
        SimParams::lan_cluster().with_records(),
        SimParams::lan_2008().with_records(),
        SimParams::datacenter().with_records(),
        SimParams::flat_logp(10e-6, 2e-6, 3e-6).with_records(),
        speedy,
        // Injected faults ride the same differential: a straggler
        // machine, a mid-schedule rank death, and both at once (machine
        // 0 / rank 0 exist on every topology; a slowdown keyed to a
        // machine the cluster doesn't have must be ignored by both
        // engines). Report equality covers the record stream and the
        // suppressed-transfer count.
        SimParams::lan_cluster().with_records().with_slowdown(0, 9.0),
        SimParams::lan_cluster().with_records().with_dead_rank(0, 1),
        SimParams::lan_2008()
            .with_records()
            .with_slowdown(0, 3.5)
            .with_slowdown(5, 2.0)
            .with_dead_rank(0, 0),
    ]
}

fn random_cluster(seed: u64, rng: &mut Rng) -> Cluster {
    if rng.gen_bool(0.5) {
        switched(
            1 + rng.gen_range(0..6),
            1 + rng.gen_range(0..6),
            1 + rng.gen_range(0..4),
        )
    } else {
        gnp(
            2 + rng.gen_range(0..6),
            0.5,
            1 + rng.gen_range(0..4),
            1 + rng.gen_range(0..3),
            seed,
        )
    }
}

fn check_exact(
    ctx_label: &str,
    cl: &Cluster,
    pl: &Placement,
    ctx: &TopoCtx,
    schedule: &Schedule,
    params: &[SimParams],
    arena: &mut SimArena,
) {
    let low = match LoweredSchedule::compile(ctx, schedule) {
        Ok(low) => low,
        Err(_) => {
            // Lowering rejects exactly what the reference engine rejects
            // (shape/connectivity); both must fail together.
            assert!(
                simulate_reference(cl, pl, schedule, &params[0]).is_err(),
                "{ctx_label}: lowering rejected a schedule the reference accepts"
            );
            return;
        }
    };
    for p in params {
        let golden = simulate_reference(cl, pl, schedule, p)
            .unwrap_or_else(|e| panic!("{ctx_label}: reference failed: {e}"));
        let fast = simulate_lowered(&low, p, arena);
        assert_eq!(
            golden.t_end.to_bits(),
            fast.t_end.to_bits(),
            "{ctx_label}: t_end diverged ({} vs {})",
            golden.t_end,
            fast.t_end
        );
        assert_eq!(golden, fast, "{ctx_label}: full report diverged");
    }
}

/// The acceptance property: on randomized topologies × collectives ×
/// duplex settings, the lowered simulator reproduces the reference's
/// `t_end`, `ext_messages` and `ext_bytes` exactly (we assert the whole
/// report, records included).
#[test]
fn lowered_simulator_matches_reference_exactly() {
    let params = param_grid();
    let mut arena = SimArena::new();
    let mut schedules_checked = 0usize;
    for seed in 0..10u64 {
        let mut rng = Rng::seed_from_u64(seed * 0x9E37 + 1);
        let cl = random_cluster(seed, &mut rng);
        let pl = Placement::block(&cl);
        let ctx = TopoCtx::new(&cl, &pl);
        let root = rng.gen_range(0..pl.num_ranks());
        let colls = [
            Collective::Broadcast { root },
            Collective::Gather { root },
            Collective::Scatter { root },
            Collective::Reduce { root },
            Collective::Allgather,
            Collective::AllToAll,
            Collective::Allreduce,
            Collective::ReduceScatter,
        ];
        for coll in colls {
            for id in candidates_for(coll, &cl, &pl) {
                let built = match id.build(&cl, &pl) {
                    Ok(s) => s,
                    Err(_) => continue, // builder inapplicable (e.g. pow2)
                };
                // Randomized payload size: the engines read per-chunk
                // bytes from the schedule's MsgSpec (uneven tails
                // included), so the differential sweep must cover the
                // size dimension, not just the default sizing.
                let built =
                    built.with_total_bytes(1 + rng.gen_range(0..(4 << 20)) as u64);
                let label = format!(
                    "seed {seed} {} {} ({} B)",
                    coll.name(),
                    id.label(),
                    built.msg.total_bytes
                );
                check_exact(&label, &cl, &pl, &ctx, &built, &params, &mut arena);
                schedules_checked += 1;
                // Both duplex legalizations of the raw candidate.
                for duplex in [Duplex::Full, Duplex::Half] {
                    let model = Multicore { duplex, ..Multicore::default() };
                    let legal = legalize(&model, &cl, &pl, &built);
                    let label = format!("{label} legalized/{duplex:?}");
                    check_exact(&label, &cl, &pl, &ctx, &legal, &params, &mut arena);
                    schedules_checked += 1;
                }
            }
        }
    }
    assert!(
        schedules_checked > 100,
        "suite degenerated: only {schedules_checked} schedules checked"
    );
}

/// The wrapper (`sim::simulate`) is the lowered path: it must agree with
/// the reference too, including on error cases.
#[test]
fn wrapper_matches_reference() {
    let params = SimParams::lan_cluster().with_records();
    for seed in [3u64, 11, 27] {
        let cl = switched(1 + (seed as usize % 5), 2, 1);
        let pl = Placement::block(&cl);
        for coll in [Collective::Broadcast { root: 0 }, Collective::Allreduce] {
            for id in candidates_for(coll, &cl, &pl) {
                let Ok(s) = id.build(&cl, &pl) else { continue };
                let a = simulate_reference(&cl, &pl, &s, &params).unwrap();
                let b = mcomm::sim::simulate(&cl, &pl, &s, &params).unwrap();
                assert_eq!(a, b, "{}", id.label());
            }
        }
    }
}
