//! Property tests for the autotuner: across randomized topologies, the
//! tuned choice is (a) semantically correct, (b) legal under the paper's
//! model, and (c) **never worse in simulated time than the flat
//! baseline** — the tuner's contract. Plus cache behavior: a second
//! lookup with the same fingerprint is a hit and returns the identical
//! decision.

use mcomm::model::CostModel;
use mcomm::sched::symexec;
use mcomm::sim::simulate;
use mcomm::topology::{switched, Cluster, Placement};
use mcomm::tune::{
    self, flat_baseline, Collective, DecisionCache, Fingerprint, TuneCfg,
};
use mcomm::util::Rng;

/// Random switched cluster (flat baselines need any-to-any reachability).
fn random_switched(seed: u64) -> Cluster {
    let mut rng = Rng::seed_from_u64(seed);
    let machines = 1 + rng.gen_range(0..6);
    let cores = 1 + rng.gen_range(0..6);
    let nics = 1 + rng.gen_range(0..4);
    switched(machines, cores, nics)
}

fn collectives_under_test(n: usize, rng: &mut Rng) -> Vec<Collective> {
    let root = rng.gen_range(0..n);
    vec![
        Collective::Broadcast { root },
        Collective::Gather { root },
        Collective::Scatter { root },
        Collective::Reduce { root },
        Collective::Allgather,
        Collective::AllToAll,
        Collective::Allreduce,
        Collective::ReduceScatter,
    ]
}

/// The acceptance property: tuned simulated time <= flat baseline
/// simulated time, on every randomized topology, for every collective.
/// The baseline is recomputed independently here (build -> legalize if
/// needed -> simulate) rather than trusting `Decision::baseline_sim`.
#[test]
fn tuned_choice_never_loses_to_flat_baseline() {
    let cfg = TuneCfg::default();
    for seed in 0..30u64 {
        let cl = random_switched(seed);
        let pl = Placement::block(&cl);
        let mut rng = Rng::seed_from_u64(seed ^ 0x7E57);
        for coll in collectives_under_test(pl.num_ranks(), &mut rng) {
            let ctx = format!("seed {seed}, {}", coll.name());
            let d = tune::select(&cl, &pl, coll, &cfg)
                .unwrap_or_else(|e| panic!("{ctx}: select: {e}"));

            // (a) semantic correctness, (b) model legality.
            symexec::verify(d.schedule())
                .unwrap_or_else(|e| panic!("{ctx}: symexec: {e}"));
            cfg.model
                .validate(&cl, &pl, d.schedule())
                .unwrap_or_else(|e| panic!("{ctx}: validate: {e}"));

            // (c) the contract, against an independently computed
            // baseline, sized exactly as the tuner sizes its candidates.
            let base_id = flat_baseline(coll, &cl).expect("switched => baseline");
            let built = base_id.build(&cl, &pl).unwrap().with_total_bytes(cfg.msg_bytes);
            let base = if cfg.model.validate(&cl, &pl, &built).is_ok() {
                built
            } else {
                mcomm::model::legalize(&cfg.model, &cl, &pl, &built)
            };
            let base_t = simulate(&cl, &pl, &base, &cfg.sim).unwrap().t_end;
            assert!(
                d.sim_time <= base_t + 1e-12,
                "{ctx}: tuned {} ({}) > baseline {} ({})",
                d.sim_time,
                d.choice.label(),
                base_t,
                base_id.label(),
            );
            assert_eq!(
                d.baseline_sim,
                Some(base_t),
                "{ctx}: reported baseline mismatch"
            );
        }
    }
}

/// Size-aware selection: across a randomized switched family, the tuned
/// decision must *change* between a small and a large payload on at
/// least one topology, and on large payloads the winning pick must be a
/// segmented pipeline that strictly beats the unsegmented flat baseline
/// in simulated time on at least one topology. (Seeds are fixed, so
/// this is deterministic.)
#[test]
fn tuned_decision_changes_across_size_sweep() {
    let small_cfg = TuneCfg::default().with_msg_bytes(512);
    let large_cfg = TuneCfg::default().with_msg_bytes(32 << 20);
    let mut decision_changed = 0usize;
    let mut segmented_wins = 0usize;
    let mut multi_machine = 0usize;
    for seed in 0..12u64 {
        let cl = random_switched(seed);
        let pl = Placement::block(&cl);
        if cl.num_machines() < 2 {
            continue; // single machine: no network, size cannot matter
        }
        multi_machine += 1;
        let coll = Collective::Broadcast { root: 0 };
        let small = tune::select(&cl, &pl, coll, &small_cfg).unwrap();
        let large = tune::select(&cl, &pl, coll, &large_cfg).unwrap();
        symexec::verify(large.schedule()).unwrap();
        if small.choice != large.choice {
            decision_changed += 1;
        }
        let base = large.baseline_sim.expect("switched => baseline");
        if large.segments() > 1 && large.sim_time < base {
            segmented_wins += 1;
        }
        // Small payloads should never pay for pipelining overhead.
        assert_eq!(small.segments(), 1, "seed {seed}: 512 B picked segmentation");
    }
    assert!(multi_machine >= 5, "degenerate sweep: {multi_machine} topologies");
    assert!(
        decision_changed >= 1,
        "no topology re-tuned between 512 B and 32 MiB"
    );
    assert!(
        segmented_wins >= 1,
        "no large-payload pick was a segmented pipeline beating the flat baseline"
    );
}

/// Robustness property: under any sampled straggler distribution (the
/// draws replicated here exactly as the tuner samples them), the
/// robust pick's mean degraded makespan never exceeds the clean pick's,
/// the reported `robust_sim` bit-matches an independent replay, and the
/// robust pick still honors the clean baseline contract — while a
/// clean-tuned decision carries no robust score at all.
#[test]
fn robust_pick_degrades_no_worse_than_clean_pick() {
    for seed in 0..12u64 {
        let cl = random_switched(seed);
        let pl = Placement::block(&cl);
        let mut rng = Rng::seed_from_u64(seed ^ 0x0B57);
        let draws = 2 + rng.gen_range(0..3);
        let rob_seed = rng.next_u64();
        let factor = 4.0 + rng.gen_range(0..5) as f64 * 4.0;
        // The tuner's sampler: `draws` uniform machine picks.
        let mut dr = Rng::seed_from_u64(rob_seed);
        let machines: Vec<usize> =
            (0..draws).map(|_| dr.gen_range(0..cl.num_machines())).collect();

        for coll in [Collective::Broadcast { root: 0 }, Collective::Allreduce] {
            let ctx = format!("seed {seed}, {}", coll.name());
            let cfg_clean = TuneCfg::default();
            let cfg_rob = cfg_clean.clone().with_robustness(draws, rob_seed, factor);
            let clean = tune::select(&cl, &pl, coll, &cfg_clean).unwrap();
            let robust = tune::select(&cl, &pl, coll, &cfg_rob).unwrap();
            assert_eq!(clean.robust_sim, None, "{ctx}: clean tuning scored robustly");

            // Mean degraded makespan over the sampled draws, accumulated
            // in draw order — the tuner's float order.
            let mean = |s: &mcomm::sched::Schedule| -> f64 {
                let mut acc = 0.0f64;
                for &m in &machines {
                    let p = cfg_rob.sim.clone().with_slowdown(m, factor);
                    acc += simulate(&cl, &pl, s, &p).unwrap().t_end / draws as f64;
                }
                acc
            };
            let clean_degraded = mean(clean.schedule());
            let robust_degraded = mean(robust.schedule());
            assert!(
                robust_degraded <= clean_degraded + 1e-12,
                "{ctx}: robust pick {} degrades to {robust_degraded}, \
                 clean pick {} only to {clean_degraded}",
                robust.choice.label(),
                clean.choice.label(),
            );
            let reported = robust
                .robust_sim
                .unwrap_or_else(|| panic!("{ctx}: robust scoring left no score"));
            assert_eq!(
                reported.to_bits(),
                robust_degraded.to_bits(),
                "{ctx}: robust_sim {reported} != replay {robust_degraded}"
            );
            // The clean contract survives robust scoring.
            let base = robust.baseline_sim.expect("switched => baseline");
            assert!(
                robust.sim_time <= base + 1e-12,
                "{ctx}: robust pick broke the baseline contract"
            );
        }
    }
}

/// Cache contract: same fingerprint => hit, identical decision; the
/// fingerprint computed standalone matches what the cache keys on.
#[test]
fn cache_hits_on_repeated_fingerprint() {
    let cfg = TuneCfg::default();
    let cache = DecisionCache::new();
    for seed in 0..10u64 {
        let cl = random_switched(seed);
        let pl = Placement::block(&cl);
        let coll = Collective::Broadcast { root: 0 };

        let first = cache.get_or_tune(&cl, &pl, coll, &cfg).unwrap().schedule.clone();
        let second = cache.get_or_tune(&cl, &pl, coll, &cfg).unwrap().schedule.clone();
        assert_eq!(first, second, "seed {seed}: cache must return the same schedule");

        // The standalone fingerprint probes the same entry.
        let fp = Fingerprint::new(&cl, &pl, coll, &cfg);
        assert!(cache.lookup(&fp).is_some(), "seed {seed}: fingerprint mismatch");
    }
    let stats = cache.stats();
    assert_eq!(stats.entries, 10);
    // Per seed: one miss, one hit from get_or_tune, one hit from lookup.
    assert_eq!((stats.hits, stats.misses), (20, 10));
}

/// Warm-start differential property (the serving-layer guarantee): a
/// seeded `select` is bit-identical to a cold `select`, field by field,
/// *whatever* candidate is hinted — the hint only permutes the stage-2
/// pool, and the winner is the argmin under a strict total order. Sweeps
/// randomized topologies/sizes through every applicable hint, both
/// placements (quotient-eligible block and quotient-ineligible
/// round-robin), robust scoring, and both sides of the
/// `quotient_sim_cap` boundary.
#[test]
fn warm_started_select_is_bit_identical_to_cold() {
    fn assert_seeded_matches_cold(
        cl: &Cluster,
        pl: &Placement,
        coll: Collective,
        cfg: &TuneCfg,
        ctx: &str,
    ) {
        let cold = tune::select(cl, pl, coll, cfg).unwrap();
        for hint in tune::candidates_for(coll, cl, pl) {
            let ctx = format!("{ctx}, hint {}", hint.label());
            let warm = tune::select_seeded(cl, pl, coll, cfg, Some(hint)).unwrap();
            assert_eq!(cold.choice, warm.choice, "{ctx}");
            assert_eq!(cold.schedule, warm.schedule, "{ctx}");
            assert_eq!(cold.model_cost.to_bits(), warm.model_cost.to_bits(), "{ctx}");
            assert_eq!(cold.sim_time.to_bits(), warm.sim_time.to_bits(), "{ctx}");
            assert_eq!(
                cold.baseline_sim.map(f64::to_bits),
                warm.baseline_sim.map(f64::to_bits),
                "{ctx}"
            );
            assert_eq!(
                cold.robust_sim.map(f64::to_bits),
                warm.robust_sim.map(f64::to_bits),
                "{ctx}"
            );
            assert_eq!(
                (cold.considered, cold.simulated),
                (warm.considered, warm.simulated),
                "{ctx}"
            );
        }
        // A hint from a foreign collective (never applicable here) is a
        // silent no-op fallback, not an error.
        let foreign = Collective::Gather { root: 0 };
        if coll != foreign {
            let alien = tune::candidates_for(foreign, cl, pl)
                .into_iter()
                .find(|id| !tune::candidates_for(coll, cl, pl).contains(id));
            if let Some(alien) = alien {
                let warm = tune::select_seeded(cl, pl, coll, cfg, Some(alien)).unwrap();
                assert_eq!(cold.choice, warm.choice, "{ctx}: alien hint");
                assert_eq!(cold.schedule, warm.schedule, "{ctx}: alien hint");
            }
        }
    }

    for seed in 0..6u64 {
        let cl = random_switched(seed);
        let mut rng = Rng::seed_from_u64(seed ^ 0xA11);
        let msg = 1u64 << (9 + rng.gen_range(0..14));
        let cfg = TuneCfg::default().with_msg_bytes(msg);
        for pl in [Placement::block(&cl), Placement::round_robin(&cl)] {
            for coll in [
                Collective::Broadcast { root: 0 },
                Collective::Allreduce,
                Collective::AllToAll,
            ] {
                let ctx = format!("seed {seed}, {} B, {}", msg, coll.name());
                assert_seeded_matches_cold(&cl, &pl, coll, &cfg, &ctx);
            }
        }
    }

    // Robust scoring changes the argmin tuple, not its order-invariance.
    let cl = switched(4, 4, 2);
    let pl = Placement::block(&cl);
    let robust = TuneCfg::default().with_robustness(2, 7, 8.0);
    assert_seeded_matches_cold(&cl, &pl, Collective::Allreduce, &robust, "robust");

    // The quotient_sim_cap boundary: the same 8x4 grid tuned below the
    // cap (pool materialized, schedule carried) and above it
    // (representative confirmation, schedule = None).
    let cl = switched(8, 4, 2);
    let pl = Placement::block(&cl);
    assert_seeded_matches_cold(&cl, &pl, Collective::Allreduce, &TuneCfg::default(), "below cap");
    let mut above = TuneCfg::default();
    above.quotient_sim_cap = 16;
    assert_seeded_matches_cold(&cl, &pl, Collective::Allreduce, &above, "above cap");
}

/// Distinct topologies must not collide: tuning 2 different shapes yields
/// 2 cache entries even when machine/core counts only differ slightly.
#[test]
fn cache_misses_across_topologies() {
    let cfg = TuneCfg::default();
    let cache = DecisionCache::new();
    for (m, c, k) in [(2usize, 2usize, 1usize), (2, 2, 2), (2, 3, 1), (3, 2, 1)] {
        let cl = switched(m, c, k);
        let pl = Placement::block(&cl);
        cache.get_or_tune(&cl, &pl, Collective::Allreduce, &cfg).unwrap();
    }
    let stats = cache.stats();
    assert_eq!((stats.hits, stats.misses, stats.entries), (0, 4, 4));
}
