//! Recovery suite: the supervised failure policy end-to-end.
//!
//! Exercises the full classify → retry → repair → replan → degrade
//! ladder on real executions with injected faults, and pins the
//! acceptance properties:
//!
//! * a repaired collective's outputs are **bit-identical** to a
//!   from-scratch run on the survivor topology (integer-valued f32
//!   payloads make every summation order exact, so `to_bits` equality is
//!   the honest check);
//! * the transient-retry path is **bounded** — attempts and backoff are
//!   capped by the policy and the whole episode stays far under a 2 s
//!   wall budget;
//! * degradation is **never silent** — a partial result carries the
//!   survivor contribution set, names the dead, and fails a full-set
//!   collection loudly.
//!
//! Edge cases from the issue: death at round 0, collective-root death,
//! a death that empties a machine, and two simultaneous deaths on the
//! same machine.

use std::time::{Duration, Instant};

use mcomm::coordinator::{
    collect_reduced_grads, collect_reduced_grads_of, seed_grad_store, AllreduceAlgo,
    BroadcastAlgo, Communicator, FailurePolicy, RecoveryOutcome,
};
use mcomm::exec::{BufferStore, ExecParams};
use mcomm::sched::{Chunk, CollectiveOp, ContribSet, Schedule};
use mcomm::topology::switched;

const P: usize = 40; // gradient elements

/// Integer-valued gradients: f32 sums are exact in any association, so
/// recovered results can be compared bit-for-bit.
fn grads(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|r| (0..P).map(|i| ((r + 2) * (i % 17 + 1)) as f32).collect())
        .collect()
}

fn survivor_sum(g: &[Vec<f32>], survivors: &[usize]) -> Vec<f32> {
    (0..P)
        .map(|i| survivors.iter().map(|&r| g[r][i]).sum::<f32>())
        .collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
    }
}

fn ring_allreduce(comm: &Communicator) -> Schedule {
    let mut s = comm.allreduce(AllreduceAlgo::Ring).unwrap();
    s.set_payload(4 * P as u64, 4);
    s
}

/// Tentpole acceptance: a mid-collective death is repaired in place and
/// the patched outputs match a from-scratch run on the survivor
/// topology bit-for-bit.
#[test]
fn repaired_allreduce_is_bit_identical_to_survivor_run() {
    let mut comm = Communicator::block(switched(3, 2, 1));
    let n = comm.num_ranks(); // 6
    let g = grads(n);
    let s = ring_allreduce(&comm);
    let seed = |sch: &Schedule, rank: usize, orig: usize| {
        seed_grad_store(sch, rank, &g[orig])
    };
    // Rank 4 dies at round 1 — mid reduce-scatter, every survivor
    // contribution still reachable, so repair must succeed.
    let params = ExecParams::zero().with_dead_rank(4, 1).with_abort_on_death();
    let sup = comm
        .supervised_execute(&s, &seed, &params, &FailurePolicy::default())
        .unwrap();

    match &sup.outcome {
        RecoveryOutcome::Repaired { dead_ranks, cut, patch_rounds, patch_cost } => {
            assert_eq!(dead_ranks, &vec![4]);
            assert_eq!(*cut, 1);
            assert!(*patch_rounds > 0, "patch must add rounds");
            assert!(*patch_cost > 0.0, "patch must be priced");
        }
        o => panic!("expected Repaired, got {o:?}"),
    }
    assert_eq!(sup.attempts, 1);
    assert_eq!(sup.report.dead_ranks, vec![4]);

    let survivors = [0usize, 1, 2, 3, 5];
    let repaired =
        collect_reduced_grads_of(&s, &sup.report.outputs[0], &survivors, P).unwrap();
    // Every survivor converged to the same bits.
    let also =
        collect_reduced_grads_of(&s, &sup.report.outputs[5], &survivors, P).unwrap();
    assert_bits_eq(&repaired, &also, "survivor stores agree");

    // From-scratch reference on the survivor topology (dense renumber).
    let mut ref_comm = Communicator::block(switched(3, 2, 1));
    ref_comm.replan_without(&[4], &[]).unwrap();
    let s2 = ring_allreduce(&ref_comm);
    let inputs: Vec<BufferStore> = survivors
        .iter()
        .enumerate()
        .map(|(new, &old)| seed_grad_store(&s2, new, &g[old]))
        .collect();
    let rep = ref_comm.execute(&s2, inputs, &ExecParams::zero()).unwrap();
    let reference =
        collect_reduced_grads(&s2, &rep.outputs[0], survivors.len(), P).unwrap();
    assert_bits_eq(&repaired, &reference, "repaired vs from-scratch survivor run");
}

/// Edge case: death at round 0 — nothing escaped the corpse yet; repair
/// rebuilds the survivor reduction from initial state.
#[test]
fn death_at_round_zero_repairs_from_initial_state() {
    let mut comm = Communicator::block(switched(3, 2, 1));
    let g = grads(comm.num_ranks());
    let s = ring_allreduce(&comm);
    let seed = |sch: &Schedule, rank: usize, orig: usize| {
        seed_grad_store(sch, rank, &g[orig])
    };
    let params = ExecParams::zero().with_dead_rank(1, 0).with_abort_on_death();
    let sup = comm
        .supervised_execute(&s, &seed, &params, &FailurePolicy::default())
        .unwrap();
    match &sup.outcome {
        RecoveryOutcome::Repaired { dead_ranks, cut, .. } => {
            assert_eq!(dead_ranks, &vec![1]);
            assert_eq!(*cut, 0, "death at round 0 means an empty prefix");
        }
        o => panic!("expected Repaired, got {o:?}"),
    }
    let survivors = [0usize, 2, 3, 4, 5];
    let got =
        collect_reduced_grads_of(&s, &sup.report.outputs[0], &survivors, P).unwrap();
    assert_bits_eq(&got, &survivor_sum(&g, &survivors), "round-0 repair");
}

/// Acceptance: the straggle path retries a bounded number of times with
/// capped backoff, then accepts the (correct) slow result — all well
/// under a 2 s wall budget.
#[test]
fn transient_straggle_retry_is_bounded() {
    let mut comm = Communicator::block(switched(2, 2, 1));
    let n = comm.num_ranks(); // 4
    let g = grads(n);
    let s = ring_allreduce(&comm);
    let seed = |sch: &Schedule, rank: usize, orig: usize| {
        seed_grad_store(sch, rank, &g[orig])
    };
    // A zero round-timeout classifies every run as slow: the supervisor
    // must exhaust its bounded retries and then accept, flagged.
    let policy = FailurePolicy {
        round_timeout: Some(Duration::ZERO),
        max_retries: 3,
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
        ..FailurePolicy::default()
    };
    let t0 = Instant::now();
    let sup = comm
        .supervised_execute(&s, &seed, &ExecParams::zero(), &policy)
        .unwrap();
    let wall = t0.elapsed();
    assert!(wall < Duration::from_secs(2), "bounded episode took {wall:?}");
    assert_eq!(sup.outcome, RecoveryOutcome::Straggled { retries: 3 });
    assert_eq!(sup.attempts, policy.max_retries + 1);
    assert!(sup.backoff_total <= policy.max_total_backoff());
    // Slow, not wrong: data is the full reduction.
    let all: Vec<usize> = (0..n).collect();
    let got = collect_reduced_grads(&s, &sup.report.outputs[0], n, P).unwrap();
    assert_bits_eq(&got, &survivor_sum(&g, &all), "straggled result");
}

/// Edge case: the broadcast root dies before its data escapes. Repair is
/// impossible (no live donor holds the payload), so the supervisor must
/// re-plan: survivors renumbered, a surviving rank promoted to root.
#[test]
fn dead_broadcast_root_replans_to_survivor_root() {
    let mut comm = Communicator::block(switched(3, 2, 1));
    let data: Vec<f32> = (1..=12).map(|x| x as f32).collect();
    let mut s = comm.broadcast(BroadcastAlgo::Binomial, 0);
    s.set_payload(4 * data.len() as u64, 4);
    // Schedule-aware seeding: whatever schedule executes, its root gets
    // the payload (after the re-plan that is the promoted survivor).
    let seed = |sch: &Schedule, rank: usize, _orig: usize| {
        let mut store = BufferStore::default();
        if let CollectiveOp::Broadcast { root } = sch.op {
            if rank == root {
                for raw in 0..sch.msg.num_chunks() {
                    let (lo, hi) = sch.msg.chunk_elem_range_raw(raw);
                    store.seed(
                        Chunk(raw),
                        ContribSet::singleton(root),
                        data[lo as usize..hi as usize].to_vec(),
                    );
                }
            }
        }
        store
    };
    let params = ExecParams::zero().with_dead_rank(0, 0).with_abort_on_death();
    let sup = comm
        .supervised_execute(&s, &seed, &params, &FailurePolicy::default())
        .unwrap();
    match &sup.outcome {
        RecoveryOutcome::Replanned { dead_ranks, survivors } => {
            assert_eq!(dead_ranks, &vec![0]);
            assert_eq!(*survivors, 5);
        }
        o => panic!("expected Replanned, got {o:?}"),
    }
    let s2 = sup.replanned_schedule.as_ref().expect("replanned schedule");
    let CollectiveOp::Broadcast { root } = s2.op else {
        panic!("replanned op changed: {:?}", s2.op)
    };
    assert_eq!(root, 0, "old rank 1 is the promoted root, renumbered to 0");
    assert_eq!(comm.num_ranks(), 5, "communicator shrank");
    // Every survivor received the promoted root's payload.
    for r in 0..5 {
        let mut got = vec![0.0f32; data.len()];
        for raw in 0..s2.msg.num_chunks() {
            let (lo, hi) = s2.msg.chunk_elem_range_raw(raw);
            if lo == hi {
                continue;
            }
            let v = sup.report.outputs[r]
                .assemble(Chunk(raw), &ContribSet::singleton(root))
                .unwrap();
            got[lo as usize..hi as usize].copy_from_slice(&v);
        }
        assert_bits_eq(&got, &data, &format!("survivor {r} payload"));
    }
}

/// Edge case: both ranks of one machine die at round 0 — the repair path
/// rebuilds the survivor reduction entirely across the remaining
/// machines.
#[test]
fn machine_emptying_death_repairs_across_machines() {
    let mut comm = Communicator::block(switched(3, 2, 1));
    let g = grads(comm.num_ranks());
    let s = ring_allreduce(&comm);
    let seed = |sch: &Schedule, rank: usize, orig: usize| {
        seed_grad_store(sch, rank, &g[orig])
    };
    // Ranks 2 and 3 are all of machine 1.
    let params = ExecParams::zero()
        .with_dead_rank(2, 0)
        .with_dead_rank(3, 0)
        .with_abort_on_death();
    let sup = comm
        .supervised_execute(&s, &seed, &params, &FailurePolicy::default())
        .unwrap();
    match &sup.outcome {
        RecoveryOutcome::Repaired { dead_ranks, cut, .. } => {
            assert_eq!(dead_ranks, &vec![2, 3]);
            assert_eq!(*cut, 0);
        }
        o => panic!("expected Repaired, got {o:?}"),
    }
    let survivors = [0usize, 1, 4, 5];
    let got =
        collect_reduced_grads_of(&s, &sup.report.outputs[0], &survivors, P).unwrap();
    assert_bits_eq(&got, &survivor_sum(&g, &survivors), "machine-emptying repair");
}

/// When repair is disabled the same machine-emptying death falls back to
/// a re-plan: the emptied machine disappears from the topology and the
/// re-executed collective completes on the dense survivor numbering.
#[test]
fn forced_replan_drops_emptied_machine() {
    let mut comm = Communicator::block(switched(3, 2, 1));
    let g = grads(comm.num_ranks());
    let s = ring_allreduce(&comm);
    let seed = |sch: &Schedule, rank: usize, orig: usize| {
        seed_grad_store(sch, rank, &g[orig])
    };
    let policy = FailurePolicy { allow_repair: false, ..FailurePolicy::default() };
    let params = ExecParams::zero()
        .with_dead_rank(2, 1)
        .with_dead_rank(3, 1)
        .with_abort_on_death();
    let sup = comm.supervised_execute(&s, &seed, &params, &policy).unwrap();
    match &sup.outcome {
        RecoveryOutcome::Replanned { dead_ranks, survivors } => {
            assert_eq!(dead_ranks, &vec![2, 3]);
            assert_eq!(*survivors, 4);
        }
        o => panic!("expected Replanned, got {o:?}"),
    }
    assert_eq!(comm.cluster.num_machines(), 2, "emptied machine dropped");
    assert_eq!(comm.num_ranks(), 4);
    let s2 = sup.replanned_schedule.as_ref().expect("replanned schedule");
    let got = collect_reduced_grads(s2, &sup.report.outputs[0], 4, P).unwrap();
    assert_bits_eq(
        &got,
        &survivor_sum(&g, &[0, 1, 4, 5]),
        "replanned survivor reduction",
    );
}

/// Edge case: two simultaneous deaths on the *same* machine (which keeps
/// other live ranks) are repaired in one pass.
#[test]
fn two_deaths_same_machine_repaired_in_one_pass() {
    let mut comm = Communicator::block(switched(2, 4, 1));
    let n = comm.num_ranks(); // 8; machine 0 = ranks 0..4
    let g = grads(n);
    let s = ring_allreduce(&comm);
    let seed = |sch: &Schedule, rank: usize, orig: usize| {
        seed_grad_store(sch, rank, &g[orig])
    };
    let params = ExecParams::zero()
        .with_dead_rank(2, 0)
        .with_dead_rank(3, 0)
        .with_abort_on_death();
    let sup = comm
        .supervised_execute(&s, &seed, &params, &FailurePolicy::default())
        .unwrap();
    match &sup.outcome {
        RecoveryOutcome::Repaired { dead_ranks, .. } => {
            assert_eq!(dead_ranks, &vec![2, 3], "both deaths handled together");
        }
        o => panic!("expected Repaired, got {o:?}"),
    }
    assert_eq!(sup.attempts, 1, "one pass, not one failed retry per corpse");
    let survivors = [0usize, 1, 4, 5, 6, 7];
    let got =
        collect_reduced_grads_of(&s, &sup.report.outputs[7], &survivors, P).unwrap();
    assert_bits_eq(&got, &survivor_sum(&g, &survivors), "same-machine double death");
}

/// Acceptance: degradation is explicit, never silent. The partial result
/// is tagged with the survivor contribution set — a consumer asking for
/// the full reduction fails loudly — and the outcome names the dead.
#[test]
fn degradation_is_explicit_never_silent() {
    let mut comm = Communicator::block(switched(2, 2, 1));
    let n = comm.num_ranks(); // 4
    let g = grads(n);
    let s = ring_allreduce(&comm);
    let seed = |sch: &Schedule, rank: usize, orig: usize| {
        seed_grad_store(sch, rank, &g[orig])
    };
    // Forbid repair and re-plan: only graceful degradation remains.
    let policy = FailurePolicy {
        allow_repair: false,
        allow_replan: false,
        ..FailurePolicy::default()
    };
    let params = ExecParams::zero().with_dead_rank(1, 2).with_abort_on_death();
    let sup = comm.supervised_execute(&s, &seed, &params, &policy).unwrap();
    match &sup.outcome {
        RecoveryOutcome::Degraded { dead_ranks, contributors } => {
            assert_eq!(dead_ranks, &vec![1], "the dead are named");
            assert_eq!(contributors, &vec![0, 2, 3], "contributors are named");
        }
        o => panic!("expected Degraded, got {o:?}"),
    }
    assert!(sup.outcome.is_degraded());
    assert_eq!(sup.report.dead_ranks, vec![1], "report carries the holes");
    // Never silent: the partial cannot masquerade as a full reduction.
    assert!(
        collect_reduced_grads(&s, &sup.report.outputs[0], n, P).is_err(),
        "full-set collection over a degraded result must fail loudly"
    );
    // But the survivor-weighted partial is exact over its contributors.
    let survivors = [0usize, 2, 3];
    let got =
        collect_reduced_grads_of(&s, &sup.report.outputs[0], &survivors, P).unwrap();
    assert_bits_eq(&got, &survivor_sum(&g, &survivors), "degraded partial");
}

/// With every recovery path disabled, a death surfaces as an explicit
/// unrecoverable error — not a silent partial, not a hang.
#[test]
fn unrecoverable_when_every_path_is_disabled() {
    let mut comm = Communicator::block(switched(2, 2, 1));
    let g = grads(comm.num_ranks());
    let s = ring_allreduce(&comm);
    let seed = |sch: &Schedule, rank: usize, orig: usize| {
        seed_grad_store(sch, rank, &g[orig])
    };
    let policy = FailurePolicy {
        allow_repair: false,
        allow_replan: false,
        allow_degrade: false,
        ..FailurePolicy::default()
    };
    let params = ExecParams::zero().with_dead_rank(1, 1).with_abort_on_death();
    let t0 = Instant::now();
    let err = comm
        .supervised_execute(&s, &seed, &params, &policy)
        .unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(2), "fail fast");
    assert!(err.to_string().contains("unrecoverable"), "{err}");
}
