//! Deterministic failure-scenario suite: every scenario that PR 6's
//! robustness machinery claims to survive, replayed end to end in
//! virtual time so the outcomes are bit-reproducible in CI.
//!
//! Scenarios:
//!   1. a rank dies mid-collective (abort mode) → the communicator
//!      re-plans onto the survivors and the next step completes, with
//!      bit-identical virtual times across independent replays;
//!   2. a single slow machine (straggler) stretches the virtual-time
//!      makespan deterministically, in both the executor and the
//!      simulator;
//!   3. membership shrinks between trainer steps (planned shrink, no
//!      death event) and the reduced group still sums exactly;
//!   4. differential: the executor's suppression-mode delivery stream
//!      equals the schedule-derived stream minus transfers touching the
//!      corpse — and the lowered simulator's record stream agrees,
//!      suppressed-transfer accounting included. The abort path on the
//!      same injection fails cleanly.

use std::sync::Arc;

use mcomm::coordinator::{
    collect_reduced_grads, seed_grad_store, AllreduceAlgo, Communicator,
};
use mcomm::exec::{self, BufferStore, ExecDelivery, ExecEngine, ExecParams, ExecPlan};
use mcomm::sched::{Chunk, LoweredSchedule, Schedule, TopoCtx, XferKind};
use mcomm::sim::{simulate, simulate_lowered, SimArena, SimParams};
use mcomm::topology::{switched, Placement};
use mcomm::tune::{candidates_for, Collective};

/// One allreduce "trainer step" over real gradient bytes: seed every
/// worker's store, execute, and check rank 0's reassembled sum.
fn step_and_check(
    comm: &Communicator,
    schedule: &Schedule,
    params: &ExecParams,
    num_params: usize,
) -> f64 {
    let w = comm.num_ranks();
    let grads: Vec<Vec<f32>> = (0..w)
        .map(|r| (0..num_params).map(|i| (r * 100 + i) as f32 * 0.25).collect())
        .collect();
    let inputs: Vec<BufferStore> =
        (0..w).map(|r| seed_grad_store(schedule, r, &grads[r])).collect();
    let rep = comm.execute(schedule, inputs, params).unwrap();
    let out = collect_reduced_grads(schedule, &rep.outputs[0], w, num_params).unwrap();
    for i in 0..num_params {
        let want: f32 = (0..w).map(|r| grads[r][i]).sum();
        assert!((out[i] - want).abs() < 1e-3, "param {i}: {} vs {want}", out[i]);
    }
    rep.virtual_time.expect("virtual mode")
}

/// Scenario 1: tuned allreduce step, rank 3 dies at round 1 (abort
/// mode), re-plan, and the next step completes on the 5 survivors.
/// The whole flow replayed from scratch is bit-identical.
fn death_replan_flow() -> (u64, u64) {
    const P: usize = 10;
    let vparams = ExecParams::lan_scaled().with_virtual_time();
    let mut comm = Communicator::block(switched(3, 2, 1));
    let mut s = comm.allreduce(AllreduceAlgo::Auto).unwrap();
    s.set_payload(4 * P as u64, 4);
    let vt_healthy = step_and_check(&comm, &s, &vparams, P);

    // Step 2 dies mid-collective: clean abort, nothing delivered.
    let dying = vparams.clone().with_dead_rank(3, 1).with_abort_on_death();
    let inputs: Vec<BufferStore> = (0..comm.num_ranks())
        .map(|r| seed_grad_store(&s, r, &vec![r as f32; P]))
        .collect();
    let err = comm.execute(&s, inputs, &dying).unwrap_err();
    assert!(err.to_string().contains("rank 3 died"), "{err}");

    // Re-plan onto the survivors and run the next step there.
    let rep = comm.replan_without(&[3], &[Collective::Allreduce]).unwrap();
    assert_eq!((rep.survivors, rep.machines), (5, 3));
    assert_eq!(rep.invalidated_decisions, 1);
    let mut s2 = comm.allreduce(AllreduceAlgo::Auto).unwrap();
    assert_eq!(s2.num_ranks, 5);
    s2.set_payload(4 * P as u64, 4);
    let vt_survivors = step_and_check(&comm, &s2, &vparams, P);
    assert!(vt_survivors > 0.0);
    (vt_healthy.to_bits(), vt_survivors.to_bits())
}

#[test]
fn rank_death_replans_and_completes_bit_reproducibly() {
    let a = death_replan_flow();
    let b = death_replan_flow();
    assert_eq!(a, b, "replay diverged: {a:?} vs {b:?}");
}

#[test]
fn straggler_machine_stretches_virtual_time_deterministically() {
    const P: usize = 8;
    let comm = Communicator::block(switched(2, 2, 1));
    let mut s = comm.allreduce(AllreduceAlgo::Ring).unwrap();
    s.set_payload(4 * P as u64, 4);
    let healthy = ExecParams::lan_scaled().with_virtual_time();
    // Both ranks of machine 1 run 8x slower (rank-keyed, virtual mode).
    let straggling = healthy.clone().with_slowdown(2, 8.0).with_slowdown(3, 8.0);

    let vt_healthy = step_and_check(&comm, &s, &healthy, P);
    let mut vts = Vec::new();
    for _ in 0..2 {
        // Fresh communicator per replay: a new worker pool must not
        // perturb the virtual clock.
        let comm = Communicator::block(switched(2, 2, 1));
        vts.push(step_and_check(&comm, &s, &straggling, P).to_bits());
    }
    assert_eq!(vts[0], vts[1], "straggler virtual time diverged");
    let vt_slow = f64::from_bits(vts[0]);
    assert!(
        vt_slow > vt_healthy,
        "slowdown must stretch the makespan: {vt_slow} <= {vt_healthy}"
    );

    // The simulator agrees qualitatively: slowing machine 1 stretches
    // the simulated makespan of the same schedule.
    let clean = simulate(&comm.cluster, &comm.placement, &s, &SimParams::lan_cluster())
        .unwrap();
    let degraded = simulate(
        &comm.cluster,
        &comm.placement,
        &s,
        &SimParams::lan_cluster().with_slowdown(1, 8.0),
    )
    .unwrap();
    assert!(degraded.t_end > clean.t_end);
}

#[test]
fn membership_shrink_between_steps_keeps_reducing_exactly() {
    const P: usize = 7; // uneven split across both group sizes
    let vparams = ExecParams::lan_scaled().with_virtual_time();
    let mut comm = Communicator::block(switched(3, 2, 1));
    let mut s = comm.allreduce(AllreduceAlgo::Auto).unwrap();
    s.set_payload(4 * P as u64, 4);
    step_and_check(&comm, &s, &vparams, P);

    // Planned shrink between steps: machine 2 leaves (no death event).
    let rep = comm.replan_without(&[4, 5], &[Collective::Allreduce]).unwrap();
    assert_eq!((rep.survivors, rep.machines), (4, 2));
    let mut s2 = comm.allreduce(AllreduceAlgo::Auto).unwrap();
    assert_eq!(s2.num_ranks, 4);
    s2.set_payload(4 * P as u64, 4);
    step_and_check(&comm, &s2, &vparams, P);
    // One pool before the shrink, one after.
    assert_eq!(comm.exec_stats().engine_spawns, 2);
}

/// The schedule-derived delivery stream minus every chunk whose
/// transfer touches a killed endpoint — the suppression-mode oracle.
fn surviving_deliveries(s: &Schedule, params: &SimParams) -> Vec<ExecDelivery> {
    let mut out = Vec::new();
    for (ri, round) in s.rounds.iter().enumerate() {
        for x in &round.xfers {
            if params.killed(x.src, ri) {
                continue;
            }
            for &d in &x.dsts {
                if params.killed(d, ri) {
                    continue;
                }
                for (ch, _) in &x.payload.items {
                    out.push(ExecDelivery {
                        round: ri as u32,
                        src: x.src as u32,
                        dst: d as u32,
                        chunk: *ch,
                        external: x.kind == XferKind::External,
                    });
                }
            }
        }
    }
    out.sort_unstable();
    out
}

/// The same filter over the lowered simulator's record stream:
/// (src, dst, external) per surviving record, plus how many the
/// injection suppressed.
fn surviving_records(s: &Schedule, params: &SimParams) -> (Vec<(usize, usize, bool)>, usize) {
    let mut out = Vec::new();
    let mut skipped = 0usize;
    for (ri, round) in s.rounds.iter().enumerate() {
        for x in &round.xfers {
            let dsts: &[usize] = match x.kind {
                XferKind::External | XferKind::LocalRead => &x.dsts[..1],
                XferKind::LocalWrite => &x.dsts[..],
            };
            for &d in dsts {
                if params.killed(x.src, ri) || params.killed(d, ri) {
                    skipped += 1;
                } else {
                    out.push((x.src, d, x.kind == XferKind::External));
                }
            }
        }
    }
    (out, skipped)
}

#[test]
fn suppressed_death_is_differential_between_exec_and_sim() {
    const DEAD: usize = 3;
    const ROUND: usize = 1;
    let pat = |r: usize, c: Chunk| vec![(r * 31 + c.0 as usize) as f32, r as f32];
    let cl = switched(2, 2, 1);
    let pl = Placement::block(&cl);
    let ctx = TopoCtx::new(&cl, &pl);
    let mut engine = ExecEngine::new(pl.num_ranks());
    let mut arena = SimArena::new();
    let exec_params = ExecParams::zero()
        .with_deliveries()
        .with_dead_rank(DEAD as u32, ROUND as u32);
    let sim_params = SimParams::lan_cluster()
        .with_records()
        .with_dead_rank(DEAD, ROUND);
    let mut suppressed_somewhere = false;

    for coll in [
        Collective::Broadcast { root: 0 },
        Collective::Allgather,
        Collective::Allreduce,
        Collective::ReduceScatter,
    ] {
        for cand in candidates_for(coll, &cl, &pl) {
            let s = cand
                .build(&cl, &pl)
                .unwrap_or_else(|e| panic!("{}: {e}", cand.label()))
                .with_total_bytes(4 << 10);
            let label = cand.label();

            // Executor, suppression mode: deliveries == schedule stream
            // minus the corpse's traffic; the death is reported when its
            // round fell inside the plan.
            let plan = Arc::new(ExecPlan::compile(&pl, &s).unwrap());
            let rep = engine
                .execute(&plan, exec::initial_inputs(&s, pat), &exec_params)
                .unwrap_or_else(|e| panic!("{label}: exec: {e}"));
            let want = surviving_deliveries(&s, &sim_params);
            assert_eq!(rep.deliveries, want, "{label}: delivery stream");
            let death_in_plan = s.rounds.len() > ROUND;
            let want_dead: Vec<u32> =
                if death_in_plan { vec![DEAD as u32] } else { Vec::new() };
            assert_eq!(rep.dead_ranks, want_dead, "{label}: dead_ranks report");

            // Lowered simulator, same injection: record stream and the
            // suppressed-transfer count match the same oracle.
            let low = LoweredSchedule::compile(&ctx, &s).unwrap();
            let sim = simulate_lowered(&low, &sim_params, &mut arena);
            let (want_recs, want_skipped) = surviving_records(&s, &sim_params);
            assert_eq!(sim.records.len(), want_recs.len(), "{label}: record count");
            for (rec, want) in sim.records.iter().zip(&want_recs) {
                assert_eq!((rec.src, rec.dst, rec.external), *want, "{label}");
            }
            assert_eq!(sim.skipped_xfers, want_skipped, "{label}: skipped count");
            let want_sim_dead: Vec<usize> =
                if death_in_plan { vec![DEAD] } else { Vec::new() };
            assert_eq!(sim.dead_ranks, want_sim_dead, "{label}: sim dead_ranks");
            suppressed_somewhere |= want_skipped > 0;

            // Abort mode on the same injection fails cleanly — and only
            // when the death round actually occurs.
            let abort = exec_params.clone().with_abort_on_death();
            let res = engine.execute(&plan, exec::initial_inputs(&s, pat), &abort);
            if death_in_plan {
                let err = res.unwrap_err();
                assert!(
                    err.to_string().contains(&format!("rank {DEAD} died")),
                    "{label}: {err}"
                );
            } else {
                res.unwrap_or_else(|e| panic!("{label}: death out of range: {e}"));
            }
        }
    }
    assert!(suppressed_somewhere, "injection never suppressed anything");
}
