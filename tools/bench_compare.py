#!/usr/bin/env python3
"""Compare a fresh BENCH_hotpath.json against the committed baseline.

The baseline (rust/BENCH_hotpath.baseline.json) is the *contract* for the
hot-path bench suite: every key listed there must be present in the
fresh run — a silently dropped bench key is how perf trajectories die.
Medians in the baseline are optional (null until a maintainer pins them
from a CI artifact); when present, the script reports the delta and only
*fails* on order-of-magnitude regressions (smoke mode on shared CI
runners is too noisy for tight gates — the artifact trail is the real
trend tracker).

Usage: bench_compare.py <fresh.json> <baseline.json>
Exit codes: 0 ok, 1 missing keys / malformed input, 2 gross regression.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 1
    try:
        with open(sys.argv[1]) as f:
            fresh = json.load(f)
        with open(sys.argv[2]) as f:
            base = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_compare: cannot read inputs: {e}")
        return 1

    base_results = base.get("results")
    if not base_results:
        print("bench_compare: FAIL — baseline has no 'results' entries "
              "(malformed baseline would make the key contract vacuous)")
        return 1
    fresh_by_name = {r["name"]: r for r in fresh.get("results", [])}
    missing = []
    regressed = []
    for want in base_results:
        name = want["name"]
        got = fresh_by_name.get(name)
        if got is None:
            missing.append(name)
            continue
        pinned = want.get("median_s")
        median = got.get("median_s")
        if not isinstance(median, (int, float)):
            missing.append(f"{name} (no median_s in fresh results)")
            continue
        if pinned:
            ratio = median / pinned
            marker = ""
            if ratio > 10.0:
                regressed.append((name, ratio))
                marker = "  <-- REGRESSION"
            print(f"  {name}: {median:.3e}s vs pinned "
                  f"{pinned:.3e}s ({ratio:.2f}x){marker}")
        else:
            print(f"  {name}: {median:.3e}s (no pinned baseline)")

    extra = sorted(set(fresh_by_name) - {r["name"] for r in base_results})
    for name in extra:
        print(f"  NEW KEY (add to baseline): {name}")

    if missing:
        print("bench_compare: FAIL — baseline keys missing from this run:")
        for name in missing:
            print(f"  - {name}")
        return 1
    if regressed:
        print("bench_compare: FAIL — gross regressions (>10x vs pinned):")
        for name, ratio in regressed:
            print(f"  - {name}: {ratio:.1f}x")
        return 2
    print(f"bench_compare: OK — {len(base_results)} keys present"
          f"{', ' + str(len(extra)) + ' new' if extra else ''}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
